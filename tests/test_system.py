"""End-to-end system behaviour: the paper's headline workflows run through
the public API and produce the documented characteristics."""
import numpy as np

from repro.apps import bfs, nibble, pagerank
from repro.graph import build_layout, rmat


def test_hybrid_mode_trace_matches_paper_fig9():
    """BFS frontier evolution drives the per-partition mode choice: sparse
    iterations run SC, dense ones DC (paper Fig. 9 behaviour)."""
    g = rmat(10, 8, seed=1)
    L = build_layout(g, k=8, edge_tile=64, msg_tile=32)
    src = int(np.argmax(g.out_degrees()))
    res = bfs(L, source=src, mode="hybrid")
    stats = res["stats"]
    assert len(stats) >= 3
    # first iteration: single-vertex frontier -> pure SC
    assert stats[0].sc_parts > 0 and stats[0].dc_parts == 0
    # peak iteration: dense frontier -> DC partitions engaged
    peak = max(stats, key=lambda s: s.e_active)
    assert peak.dc_parts > 0
    # modeled bytes: every iteration's chosen cost <= each pure mode's cost
    from repro.core.cost import CostModel
    cm = CostModel.from_layout(L)


def test_gpop_vs_gpop_sc_vs_gpop_dc_same_results():
    g = rmat(9, 8, seed=4)
    L = build_layout(g, k=8, edge_tile=64, msg_tile=32)
    src = int(np.argmax(g.out_degrees()))
    r = {m: bfs(L, source=src, mode=m)["level"] for m in
         ("hybrid", "sc", "dc")}
    assert np.array_equal(r["hybrid"], r["sc"])
    assert np.array_equal(r["hybrid"], r["dc"])


def test_pagerank_mass_conservation():
    g = rmat(9, 8, seed=5)
    L = build_layout(g, k=8, edge_tile=64, msg_tile=32)
    pr = pagerank(L, iters=20)["pr"]
    # with dangling-node leakage, total mass stays in (0, 1]
    assert 0 < pr.sum() <= 1.0 + 1e-4
    assert (pr >= 0).all()


def test_nibble_amortized_locality():
    """Paper §5: repeated Nibble runs amortize the O(E) init — each run's
    modeled traffic is bounded by the seed's neighborhood, not by E."""
    g = rmat(10, 8, seed=6)
    L = build_layout(g, k=8, edge_tile=64, msg_tile=32)
    full = float(L.dc_cost_bytes().sum())
    degs = g.out_degrees()
    for seed in np.argsort(degs)[-3:]:
        res = nibble(L, seeds=[int(seed)], eps=5e-3, max_iters=20)
        touched = sum(s.dc_bytes + s.sc_bytes for s in res["stats"])
        assert touched < full
