"""HLO cost walker: exact on known graphs, trip-count-aware on loops."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.hlo_cost import HloCostModel, analyze


def test_matmul_exact():
    x = jnp.zeros((256, 256), jnp.float32)
    c = jax.jit(lambda x: x @ x).lower(x).compile()
    a = analyze(c.as_text())
    assert a["flops"] == 2 * 256 ** 3


def test_scan_trip_count_scaling():
    x = jnp.zeros((128, 128), jnp.float32)

    def ten(x):
        def body(c, _):
            return c @ c + 1.0, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    c = jax.jit(ten).lower(x).compile()
    a = analyze(c.as_text())
    exp = 10 * 2 * 128 ** 3
    assert abs(a["flops"] - exp) / exp < 0.05


def test_nested_scan():
    x = jnp.zeros((64, 64), jnp.float32)

    def nested(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ c2, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    c = jax.jit(nested).lower(x).compile()
    a = analyze(c.as_text())
    exp = 15 * 2 * 64 ** 3
    assert abs(a["flops"] - exp) / exp < 0.05


def test_batched_dot_flops():
    a = jnp.zeros((8, 32, 64), jnp.float32)
    b = jnp.zeros((8, 64, 16), jnp.float32)
    c = jax.jit(lambda a, b: jnp.einsum("bik,bkj->bij", a, b)) \
        .lower(a, b).compile()
    r = analyze(c.as_text())
    assert r["flops"] == 2 * 8 * 32 * 64 * 16


def test_dtype_bytes_parsing():
    from repro.hlo_cost import _bytes_of
    assert _bytes_of("f32[2,3]") == 24
    assert _bytes_of("bf16[4]") == 8
    assert _bytes_of("(f32[2], s32[3]{0})") == 20
    assert _bytes_of("pred[7]") == 7
    assert _bytes_of("s32[]") == 4
