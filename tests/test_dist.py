"""Distributed PPM engine on 8 virtual host devices (subprocess: the device
count must be fixed before jax initializes, and the main test process stays
single-device)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


COMMON = """
import jax, numpy as np, jax.numpy as jnp
from repro.dist.compat import AxisType, make_mesh
from repro.graph import rmat, build_layout, to_scipy
from repro.graph.shard import shard_layout
from repro.dist.engine import DistEngine
import scipy.sparse.csgraph as csg
D = 8
mesh = make_mesh((D,), ("dev",), axis_types=(AxisType.Auto,))
g = rmat(10, 8, seed=1)
L = build_layout(g, k=16, edge_tile=64, msg_tile=32)
SL = shard_layout(L, D)
src = int(np.argmax(g.out_degrees()))
N = D * SL.nv
"""


@pytest.mark.slow
def test_dist_bfs_hybrid():
    out = _run(COMMON + """
from repro.apps.bfs import bfs_program
prog = bfs_program()
parent = np.full(N, -1, np.int32); parent[src] = src
level = np.full(N, -1, np.int32); level[src] = 0
vid = np.arange(N, dtype=np.uint32)
frontier = np.zeros(N, bool); frontier[src] = True
eng = DistEngine(SL, prog, mesh, mode="hybrid")
# the CI dist lane pins the fold backend via REPRO_KERNEL_BACKEND; the
# engine must honour it (BFS's min/uint32 monoid lowers on every backend)
import os
want = os.environ.get("REPRO_KERNEL_BACKEND")
if want:
    assert eng.backend_name == want, eng.backend_name
state, _, stats = eng.run({"parent": parent, "level": level, "vid": vid},
                          frontier)
lv = np.asarray(state["level"])[:g.n]
d = csg.shortest_path(to_scipy(g), method="D", unweighted=True, indices=src)
ref = np.where(np.isinf(d), -1, d).astype(int)
assert np.array_equal(lv, ref), "dist bfs mismatch"
modes = {s["mode"] for s in stats}
assert modes == {"sc", "dc"}, f"hybrid should use both modes: {modes}"
print("OK")
""")
    assert "OK" in out


@pytest.mark.slow
def test_dist_pagerank_dc_and_sssp_sc():
    out = _run(COMMON + """
from repro.apps.pagerank import pagerank_program
from repro.apps.sssp import sssp_program
import scipy.sparse as sp

prog = pagerank_program(g.n)
pr0 = np.zeros(N, np.float32); pr0[:g.n] = 1.0/g.n
deg = np.zeros(N, np.float32); deg[:L.n_pad] = L.deg
frontier = np.zeros(N, bool); frontier[:g.n] = True
eng = DistEngine(SL, prog, mesh, mode="dc")
state, _, _ = eng.run({"pr": pr0, "deg": deg}, frontier, max_iters=5,
                      until_empty=False)
pr = np.asarray(state["pr"])[:g.n]
x = np.full(g.n, 1.0/g.n); outdeg = g.out_degrees(); P = to_scipy(g)
for _ in range(5):
    x = 0.15/g.n + 0.85*(P.T@np.where(outdeg>0, x/np.maximum(outdeg,1), 0.0))
assert np.abs(pr-x).max() < 1e-5, "dist pagerank mismatch"

gw = rmat(9, 8, seed=2, weighted=True)
Lw = build_layout(gw, k=16, edge_tile=64, msg_tile=32)
SLw = shard_layout(Lw, D)
s2 = int(np.argmax(gw.out_degrees()))
Nw = D * SLw.nv
dist0 = np.full(Nw, np.inf, np.float32); dist0[s2] = 0
frontier = np.zeros(Nw, bool); frontier[s2] = True
eng = DistEngine(SLw, sssp_program(), mesh, mode="sc")
state, _, _ = eng.run({"dist": dist0}, frontier)
ours = np.asarray(state["dist"])[:gw.n]
d2 = csg.shortest_path(to_scipy(gw), method="D", indices=s2)
fin = ~np.isinf(d2)
assert np.allclose(ours[fin], d2[fin], atol=1e-5)
assert np.array_equal(np.isinf(ours), ~fin)
print("OK")
""")
    assert "OK" in out


@pytest.mark.slow
def test_dist_equals_single_device_engine():
    """Distributed and single-device engines agree bit-for-bit on BFS."""
    out = _run(COMMON + """
from repro.apps.bfs import bfs_program
from repro.apps import bfs as bfs_single
prog = bfs_program()
parent = np.full(N, -1, np.int32); parent[src] = src
level = np.full(N, -1, np.int32); level[src] = 0
vid = np.arange(N, dtype=np.uint32)
frontier = np.zeros(N, bool); frontier[src] = True
eng = DistEngine(SL, prog, mesh, mode="sc")
state, _, _ = eng.run({"parent": parent, "level": level, "vid": vid},
                      frontier)
res1 = np.asarray(state["parent"])[:g.n]
res2 = bfs_single(L, source=src, mode="sc")["parent"]
assert np.array_equal(res1, res2), "engines disagree"
print("OK")
""")
    assert "OK" in out


@pytest.mark.slow
def test_dist_hybrid_per_partition():
    """Per-partition dual mode at pod granularity: correct BFS AND at least
    one iteration mixing DC and SC partitions (paper Fig. 9 behaviour)."""
    out = _run(COMMON + """
from repro.apps.bfs import bfs_program
prog = bfs_program()
parent = np.full(N, -1, np.int32); parent[src] = src
level = np.full(N, -1, np.int32); level[src] = 0
vid = np.arange(N, dtype=np.uint32)
frontier = np.zeros(N, bool); frontier[src] = True
eng = DistEngine(SL, prog, mesh, mode="hybrid_pp")
state, _, stats = eng.run({"parent": parent, "level": level, "vid": vid},
                          frontier)
lv = np.asarray(state["level"])[:g.n]
d = csg.shortest_path(to_scipy(g), method="D", unweighted=True, indices=src)
ref = np.where(np.isinf(d), -1, d).astype(int)
assert np.array_equal(lv, ref), "hybrid_pp bfs mismatch"
assert any(s["dc_parts"] > 0 and s["sc_parts"] > 0 for s in stats), \
    "expected an iteration with mixed per-partition modes"
print("OK")
""")
    assert "OK" in out


@pytest.mark.slow
def test_dist_equivalence_random_graphs():
    """Property: all three distributed modes equal the single-device engine
    on random graphs (one subprocess, several seeds)."""
    out = _run("""
import numpy as np, jax
from repro.dist.compat import AxisType, make_mesh
from repro.graph import uniform_random, build_layout
from repro.graph.shard import shard_layout
from repro.dist.engine import DistEngine
from repro.apps.bfs import bfs_program
from repro.apps import bfs as bfs_single

D = 8
mesh = make_mesh((D,), ("dev",), axis_types=(AxisType.Auto,))
for seed in (3, 17, 91):
    g = uniform_random(300, 2500, seed=seed)
    L = build_layout(g, k=16, edge_tile=32, msg_tile=16)
    SL = shard_layout(L, D)
    N = D * SL.nv
    src = int(np.argmax(g.out_degrees()))
    ref = bfs_single(L, source=src, mode="hybrid")["parent"]
    for mode in ("dc", "sc", "hybrid_pp"):
        prog = bfs_program()
        parent = np.full(N, -1, np.int32); parent[src] = src
        level = np.full(N, -1, np.int32); level[src] = 0
        vid = np.arange(N, dtype=np.uint32)
        f = np.zeros(N, bool); f[src] = True
        eng = DistEngine(SL, prog, mesh, mode=mode)
        st, _, _ = eng.run({"parent": parent, "level": level, "vid": vid}, f)
        got = np.asarray(st["parent"])[:g.n]
        assert np.array_equal(got, ref), (seed, mode)
print("OK")
""")
    assert "OK" in out
