"""Property test (hypothesis): the distributed per-partition dual-mode step
(``mode='hybrid_pp'``) equals the pure ``dc`` and pure ``sc`` runs across
random graphs AND random multi-vertex frontiers, for BFS and CC.

The parity is mode-only (no oracle): all three modes execute the same
vertex program over the same sharded layout, so any divergence is a bug in
the per-partition stream split / dual-fold combine of
:func:`repro.dist.engine.build_hybrid_step`.

Runs in ONE subprocess (the 4 virtual devices must be fixed before jax
initializes; the parent test process stays single-device) with hypothesis
driving the example loop inside it — a @given-wrapped function is directly
callable, so the property executes entirely in the child.
"""
import os
import subprocess
import sys
import textwrap

import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_hybrid_pp_parity_random_graphs_and_frontiers():
    code = textwrap.dedent("""
    import numpy as np
    from hypothesis import given, settings, strategies as st

    from repro.dist.compat import AxisType, make_mesh
    from repro.dist.engine import DistEngine
    from repro.graph import build_layout, from_edges
    from repro.graph.shard import shard_layout
    from repro.apps.bfs import bfs_program
    from repro.apps.cc import cc_program

    D = 4
    mesh = make_mesh((D,), ("dev",), axis_types=(AxisType.Auto,))

    def run_app(app, SL, N, frontier, mode):
        if app == "bfs":
            prog = bfs_program()
            src = np.where(frontier)[0].astype(np.int32)
            parent = np.full(N, -1, np.int32); parent[src] = src
            level = np.full(N, -1, np.int32); level[src] = 0
            vid = np.arange(N, dtype=np.uint32)
            state = {"parent": parent, "level": level, "vid": vid}
            keys = ("parent", "level")
        else:
            prog = cc_program()
            state = {"label": np.arange(N, dtype=np.uint32)}
            keys = ("label",)
        eng = DistEngine(SL, prog, mesh, mode=mode)
        st_out, _, stats = eng.run(state, frontier)
        return {k: np.asarray(st_out[k]) for k in keys}, stats

    @settings(max_examples=5, deadline=None, derandomize=True)
    @given(st.data())
    def prop(data):
        n = data.draw(st.integers(8, 96))
        m = data.draw(st.integers(4, 512))
        seed = data.draw(st.integers(0, 10**6))
        rng = np.random.default_rng(seed)
        g = from_edges(rng.integers(0, n, m), rng.integers(0, n, m), n=n,
                       dedup=True)
        L = build_layout(g, k=8, edge_tile=16, msg_tile=8)
        SL = shard_layout(L, D)
        N = D * SL.nv
        # random multi-vertex frontier (>=1 active real vertex)
        p_act = data.draw(st.sampled_from([0.05, 0.3, 0.8]))
        frontier = np.zeros(N, bool)
        frontier[:g.n] = rng.random(g.n) < p_act
        if not frontier.any():
            frontier[rng.integers(0, g.n)] = True
        for app in ("bfs", "cc"):
            ref, _ = run_app(app, SL, N, frontier, "dc")
            sc, _ = run_app(app, SL, N, frontier, "sc")
            hy, _ = run_app(app, SL, N, frontier, "hybrid_pp")
            for k in ref:
                assert np.array_equal(sc[k], ref[k]), (app, k, "sc", seed)
                assert np.array_equal(hy[k], ref[k]), \\
                    (app, k, "hybrid_pp", seed)

    prop()
    print("OK")
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "OK" in r.stdout
