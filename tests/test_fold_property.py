"""Property tests (hypothesis): the blocked Pallas segmented folds.

The folds behind registry kernel ``fold`` — the flat
:mod:`repro.kernels.fold_block` and the two-level
:mod:`repro.kernels.fold_two_level` that takes over past
``REPRO_FOLD_MAX_SEGMENTS`` — must agree with the ``jax.ops.segment_*``
oracles (and each other) for ANY message stream: duplicate ids, empty
segments, out-of-order ids, all-invalid blocks, the ``n_pad + 1``
overflow bin, segment counts on both sides of the cap, non-power-of-two
bucket widths, and stream lengths that do not divide the message tile.
Payloads are integer-valued so even the f32 add fold is exact and the
comparison can be bit-for-bit.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.backend import registry
from repro.core import monoid as M
from repro.kernels.fold_block import (DEFAULT_FOLD_MAX_SEGMENTS,
                                      blocked_segment_fold)
from repro.kernels.fold_two_level import two_level_segment_fold

SEGMENT_OPS = {"add": jax.ops.segment_sum, "min": jax.ops.segment_min,
               "max": jax.ops.segment_max}
MONOIDS = {("add", "float32"): lambda: M.add(jnp.float32),
           ("add", "int32"): lambda: M.add(jnp.int32),
           ("min", "float32"): lambda: M.min_(jnp.float32),
           ("min", "int32"): lambda: M.min_(jnp.int32),
           ("max", "float32"): lambda: M.max_(jnp.float32),
           ("max", "int32"): lambda: M.max_(jnp.int32)}

# small closed sets keep the jit-compile count bounded while still covering
# multi-block streams, ragged tails, and the single-segment degenerate case
NUM_SEGMENTS = (1, 2, 5, 9, 17)
FOLD_TILES = (8, 16)


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_blocked_fold_matches_segment_ops(data):
    monoid, dtype = data.draw(st.sampled_from(sorted(MONOIDS)))
    mono = MONOIDS[(monoid, dtype)]()
    ns = data.draw(st.sampled_from(NUM_SEGMENTS))
    tile = data.draw(st.sampled_from(FOLD_TILES))
    n = data.draw(st.integers(0, 40))
    seed = data.draw(st.integers(0, 10**6))
    rng = np.random.default_rng(seed)

    vals = jnp.asarray(rng.integers(-64, 64, n).astype(np.dtype(dtype)))
    valid = jnp.asarray(rng.random(n) < data.draw(
        st.sampled_from([0.0, 0.5, 1.0])))
    # out-of-order + duplicates by construction; ns - 1 doubles as the
    # engines' overflow bin and must behave like any other segment
    ids = jnp.asarray(rng.integers(0, ns, n).astype(np.int32))

    acc, touched = blocked_segment_fold(vals, valid, ids, ns,
                                        monoid=monoid, fold_tile=tile,
                                        interpret=True)
    mvals = jnp.where(valid, vals, mono.identity)
    ref_acc = SEGMENT_OPS[monoid](mvals, ids, num_segments=ns)
    ref_touched = jax.ops.segment_max(valid.astype(jnp.int32), ids,
                                      num_segments=ns) > 0
    assert np.array_equal(np.asarray(acc), np.asarray(ref_acc))
    assert np.array_equal(np.asarray(touched), np.asarray(ref_touched))

    # and the registry's tightened ref fold implements the same contract
    rf = registry.BACKENDS["ref"].segment_fold(mono)
    racc, rtouched = rf(vals, valid, ids, ns)
    assert np.array_equal(np.asarray(racc), np.asarray(ref_acc))
    assert np.array_equal(np.asarray(rtouched), np.asarray(ref_touched))


@settings(max_examples=15, deadline=None)
@given(st.data())
def test_blocked_fold_all_invalid_returns_identity(data):
    monoid, dtype = data.draw(st.sampled_from(sorted(MONOIDS)))
    mono = MONOIDS[(monoid, dtype)]()
    ns = data.draw(st.sampled_from(NUM_SEGMENTS))
    n = data.draw(st.integers(0, 40))
    rng = np.random.default_rng(data.draw(st.integers(0, 10**6)))
    vals = jnp.asarray(rng.integers(-64, 64, n).astype(np.dtype(dtype)))
    ids = jnp.asarray(rng.integers(0, ns, n).astype(np.int32))
    acc, touched = blocked_segment_fold(vals, jnp.zeros((n,), jnp.bool_),
                                        ids, ns, monoid=monoid,
                                        fold_tile=8, interpret=True)
    assert np.array_equal(np.asarray(acc),
                          np.full(ns, mono.identity, np.dtype(dtype)))
    assert not np.asarray(touched).any()


# ----------------------------------------------------------------------
# two-level fold: segment counts across the REPRO_FOLD_MAX_SEGMENTS cap
# ----------------------------------------------------------------------

CAP = DEFAULT_FOLD_MAX_SEGMENTS
# closed (num_segments, fold_q) pairs keep the bucket grid small enough
# for interpret mode while covering: below / at / just past / 2x / 3x the
# cap, bucket widths that are non-powers-of-two, that don't divide the
# segment count, and that exceed it (single-bucket degenerate case)
NS_Q_PAIRS = ((8, 3), (100, 7), (1024, 2048), (CAP - 1, 512),
              (CAP, 1000), (CAP + 1, 257), (2 * CAP, 1024),
              (3 * CAP, 4096))


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_two_level_fold_matches_flat_and_segment_ops(data):
    """two-level ≡ flat blocked ≡ jax.ops.segment_* for segment counts on
    both sides of the cap (the flat kernel has no VMEM ceiling in
    interpret mode, so it can serve as a second oracle everywhere)."""
    monoid, dtype = data.draw(st.sampled_from(sorted(MONOIDS)))
    mono = MONOIDS[(monoid, dtype)]()
    ns, q = data.draw(st.sampled_from(NS_Q_PAIRS))
    tile = data.draw(st.sampled_from(FOLD_TILES))
    n = data.draw(st.integers(0, 60))
    seed = data.draw(st.integers(0, 10**6))
    rng = np.random.default_rng(seed)

    vals = jnp.asarray(rng.integers(-64, 64, n).astype(np.dtype(dtype)))
    valid = jnp.asarray(rng.random(n) < data.draw(
        st.sampled_from([0.0, 0.5, 1.0])))
    # duplicates + out-of-order by construction; ns - 1 doubles as the
    # engines' overflow bin and must behave like any other segment
    ids = jnp.asarray(rng.integers(0, ns, n).astype(np.int32))

    acc2, touched2 = two_level_segment_fold(vals, valid, ids, ns,
                                            monoid=monoid, fold_tile=tile,
                                            fold_q=q, interpret=True)
    mvals = jnp.where(valid, vals, mono.identity)
    ref_acc = SEGMENT_OPS[monoid](mvals, ids, num_segments=ns)
    ref_touched = jax.ops.segment_max(valid.astype(jnp.int32), ids,
                                      num_segments=ns) > 0
    assert np.array_equal(np.asarray(acc2), np.asarray(ref_acc))
    assert np.array_equal(np.asarray(touched2), np.asarray(ref_touched))

    facc, ftouched = blocked_segment_fold(vals, valid, ids, ns,
                                          monoid=monoid, fold_tile=tile,
                                          interpret=True)
    assert np.array_equal(np.asarray(acc2), np.asarray(facc))
    assert np.array_equal(np.asarray(touched2), np.asarray(ftouched))


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_two_level_fold_all_invalid_returns_identity(data):
    monoid, dtype = data.draw(st.sampled_from(sorted(MONOIDS)))
    mono = MONOIDS[(monoid, dtype)]()
    ns, q = data.draw(st.sampled_from(NS_Q_PAIRS))
    n = data.draw(st.integers(0, 40))
    rng = np.random.default_rng(data.draw(st.integers(0, 10**6)))
    vals = jnp.asarray(rng.integers(-64, 64, n).astype(np.dtype(dtype)))
    ids = jnp.asarray(rng.integers(0, ns, n).astype(np.int32))
    acc, touched = two_level_segment_fold(vals, jnp.zeros((n,), jnp.bool_),
                                          ids, ns, monoid=monoid,
                                          fold_tile=8, fold_q=q,
                                          interpret=True)
    assert np.array_equal(np.asarray(acc),
                          np.full(ns, mono.identity, np.dtype(dtype)))
    assert not np.asarray(touched).any()


def test_two_level_fold_out_of_range_ids_contribute_nothing():
    """The fold contract: ids outside [0, num_segments) — including
    negative and past-the-padding ids — land nowhere, for both blocked
    kernels."""
    ns, q = 10, 3
    ids = jnp.asarray(np.array([0, 5, 9, 10, 11, 50, -3, -1], np.int32))
    vals = jnp.ones((8,), jnp.float32)
    valid = jnp.ones((8,), bool)
    for fold in (
            lambda: two_level_segment_fold(vals, valid, ids, ns,
                                           monoid="add", fold_tile=4,
                                           fold_q=q, interpret=True),
            lambda: blocked_segment_fold(vals, valid, ids, ns,
                                         monoid="add", fold_tile=4,
                                         interpret=True)):
        acc, touched = fold()
        want = np.zeros(ns, np.float32)
        want[[0, 5, 9]] = 1.0
        assert np.array_equal(np.asarray(acc), want)
        assert np.array_equal(np.asarray(touched), want > 0)
