"""Property tests (hypothesis): the blocked Pallas segmented folds.

The folds behind registry kernel ``fold`` — the flat
:mod:`repro.kernels.fold_block` and the two-level
:mod:`repro.kernels.fold_two_level` that takes over past
``REPRO_FOLD_MAX_SEGMENTS`` — must agree with the ``jax.ops.segment_*``
oracles (and each other) for ANY message stream: duplicate ids, empty
segments, out-of-order ids, all-invalid blocks, the ``n_pad + 1``
overflow bin, segment counts on both sides of the cap, non-power-of-two
bucket widths, and stream lengths that do not divide the message tile.

Strategies, monoid×dtype combos, and the bit-exact comparator come from
the shared differential harness (``tests/kernel_harness.py``); payloads
are integer-valued so even the f32 add fold is exact and the comparison
can be bit-for-bit.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from kernel_harness import (FOLD_TILES, NS_Q_PAIRS, NUM_SEGMENTS,
                            assert_kernel_equiv, draw_monoid, draw_stream,
                            segment_oracle)
from repro.backend import registry
from repro.kernels.fold_block import blocked_segment_fold
from repro.kernels.fold_two_level import two_level_segment_fold


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_blocked_fold_matches_segment_ops(data):
    monoid, dtype, mono = draw_monoid(data)
    ns = data.draw(st.sampled_from(NUM_SEGMENTS))
    tile = data.draw(st.sampled_from(FOLD_TILES))
    vals, valid, ids = draw_stream(data, ns, dtype)

    assert_kernel_equiv(
        lambda v, va, i: blocked_segment_fold(v, va, i, ns, monoid=monoid,
                                              fold_tile=tile,
                                              interpret=True),
        lambda v, va, i: segment_oracle(mono, v, va, i, ns),
        (vals, valid, ids))

    # and the registry's tightened ref fold implements the same contract
    rf = registry.BACKENDS["ref"].segment_fold(mono)
    assert_kernel_equiv(
        lambda v, va, i: rf(v, va, i, ns),
        lambda v, va, i: segment_oracle(mono, v, va, i, ns),
        (vals, valid, ids))


@settings(max_examples=15, deadline=None)
@given(st.data())
def test_blocked_fold_all_invalid_returns_identity(data):
    monoid, dtype, mono = draw_monoid(data)
    ns = data.draw(st.sampled_from(NUM_SEGMENTS))
    vals, _, ids = draw_stream(data, ns, dtype)
    n = vals.shape[0]
    acc, touched = blocked_segment_fold(vals, jnp.zeros((n,), jnp.bool_),
                                        ids, ns, monoid=monoid,
                                        fold_tile=8, interpret=True)
    assert np.array_equal(np.asarray(acc),
                          np.full(ns, mono.identity, np.dtype(dtype)))
    assert not np.asarray(touched).any()


# ----------------------------------------------------------------------
# two-level fold: segment counts across the REPRO_FOLD_MAX_SEGMENTS cap
# ----------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_two_level_fold_matches_flat_and_segment_ops(data):
    """two-level ≡ flat blocked ≡ jax.ops.segment_* for segment counts on
    both sides of the cap (the flat kernel has no VMEM ceiling in
    interpret mode, so it can serve as a second oracle everywhere)."""
    monoid, dtype, mono = draw_monoid(data)
    ns, q = data.draw(st.sampled_from(NS_Q_PAIRS))
    tile = data.draw(st.sampled_from(FOLD_TILES))
    vals, valid, ids = draw_stream(data, ns, dtype, max_len=60)

    two_level = lambda v, va, i: two_level_segment_fold(
        v, va, i, ns, monoid=monoid, fold_tile=tile, fold_q=q,
        interpret=True)
    assert_kernel_equiv(
        two_level,
        lambda v, va, i: segment_oracle(mono, v, va, i, ns),
        (vals, valid, ids))
    assert_kernel_equiv(
        two_level,
        lambda v, va, i: blocked_segment_fold(v, va, i, ns, monoid=monoid,
                                              fold_tile=tile,
                                              interpret=True),
        (vals, valid, ids))


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_two_level_fold_all_invalid_returns_identity(data):
    monoid, dtype, mono = draw_monoid(data)
    ns, q = data.draw(st.sampled_from(NS_Q_PAIRS))
    vals, _, ids = draw_stream(data, ns, dtype)
    n = vals.shape[0]
    acc, touched = two_level_segment_fold(vals, jnp.zeros((n,), jnp.bool_),
                                          ids, ns, monoid=monoid,
                                          fold_tile=8, fold_q=q,
                                          interpret=True)
    assert np.array_equal(np.asarray(acc),
                          np.full(ns, mono.identity, np.dtype(dtype)))
    assert not np.asarray(touched).any()


def test_two_level_fold_out_of_range_ids_contribute_nothing():
    """The fold contract: ids outside [0, num_segments) — including
    negative and past-the-padding ids — land nowhere, for both blocked
    kernels."""
    ns, q = 10, 3
    ids = jnp.asarray(np.array([0, 5, 9, 10, 11, 50, -3, -1], np.int32))
    vals = jnp.ones((8,), jnp.float32)
    valid = jnp.ones((8,), bool)
    for fold in (
            lambda: two_level_segment_fold(vals, valid, ids, ns,
                                           monoid="add", fold_tile=4,
                                           fold_q=q, interpret=True),
            lambda: blocked_segment_fold(vals, valid, ids, ns,
                                         monoid="add", fold_tile=4,
                                         interpret=True)):
        acc, touched = fold()
        want = np.zeros(ns, np.float32)
        want[[0, 5, 9]] = 1.0
        assert np.array_equal(np.asarray(acc), want)
        assert np.array_equal(np.asarray(touched), want > 0)
