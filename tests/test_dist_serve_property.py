"""Property tests (hypothesis, 4 virtual devices): distributed batched
execution equals per-query distributed runs.

  * ``DistEngine.run_batched`` ≡ B sequential ``DistEngine.run`` calls in
    mode='dc' — BFS and SSSP, random graphs, random multi-source batches,
    with and without the compressed wire.  The parity must hold per wire
    config: both paths perform identical per-lane math, so results are
    bit-identical even when bf16 rounds SSSP distances.
  * ``wire_bf16`` exactness for id-monoids: BFS carries uint32 vertex ids
    (< 2**24 here), the bf16 cast never engages, so the compressed engine
    matches the uncompressed one bit-for-bit.
  * a DistEngine-backed :class:`repro.serve.GraphQueryServer` answers a
    drained batch identically to the single-device server.

Runs in ONE subprocess (virtual devices must be fixed before jax
initializes; the parent test process stays single-device) with hypothesis
driving the example loop inside it.
"""
import os
import subprocess
import sys
import textwrap

import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "OK" in r.stdout


COMMON = """
import numpy as np
from repro.dist.compat import AxisType, make_mesh
from repro.dist.engine import DistEngine
from repro.graph import build_layout, from_edges
from repro.graph.shard import shard_layout

D = 4
mesh = make_mesh((D,), ("dev",), axis_types=(AxisType.Auto,))

def random_sharded(data, st, weighted):
    n = data.draw(st.integers(8, 96))
    m = data.draw(st.integers(4, 512))
    seed = data.draw(st.integers(0, 10**6))
    rng = np.random.default_rng(seed)
    w = rng.random(m).astype(np.float32) if weighted else None
    g = from_edges(rng.integers(0, n, m), rng.integers(0, n, m), n=n,
                   dedup=True, weights=w)
    L = build_layout(g, k=8, edge_tile=16, msg_tile=8)
    return shard_layout(L, D), g.n, rng, data.draw(st.integers(2, 8))
"""


@pytest.mark.slow
def test_dist_run_batched_equals_per_query_runs():
    _run(COMMON + """
    from hypothesis import given, settings, strategies as st
    from repro.apps.bfs import bfs_program
    from repro.apps.sssp import sssp_program

    def states_for(app, N, sources):
        if app == "bfs":
            B = len(sources)
            parent = np.full((B, N), -1, np.int32)
            level = np.full((B, N), -1, np.int32)
            vid = np.broadcast_to(np.arange(N, dtype=np.uint32),
                                  (B, N)).copy()
            for i, s in enumerate(sources):
                parent[i, s] = s; level[i, s] = 0
            return {"parent": parent, "level": level, "vid": vid}
        dist = np.full((len(sources), N), np.inf, np.float32)
        for i, s in enumerate(sources):
            dist[i, s] = 0.0
        return {"dist": dist}

    @settings(max_examples=5, deadline=None, derandomize=True)
    @given(st.data())
    def prop(data):
        for app, weighted in (("bfs", False), ("sssp", True)):
            SL, n, rng, B = random_sharded(data, st, weighted)
            N = D * SL.nv
            prog = bfs_program() if app == "bfs" else sssp_program()
            sources = rng.integers(0, n, B)
            fr = np.zeros((B, N), bool)
            fr[np.arange(B), sources] = True
            for wire in (False, True):
                eng = DistEngine(SL, prog, mesh, mode="dc",
                                 wire_bf16=wire)
                states = states_for(app, N, sources)
                bat, _, _ = eng.run_batched(
                    {k: v.copy() for k, v in states.items()}, fr)
                for i in range(B):
                    seq, _, _ = eng.run(
                        {k: v[i].copy() for k, v in states.items()}, fr[i])
                    for k in seq:
                        same = np.array_equal(np.asarray(bat[k][i]),
                                              np.asarray(seq[k]))
                        assert same, (app, wire, k, i)
    prop()
    print("OK")
    """)


@pytest.mark.slow
def test_wire_bf16_exact_for_id_monoids():
    _run(COMMON + """
    from hypothesis import given, settings, strategies as st
    from repro.apps.bfs import bfs_program

    @settings(max_examples=5, deadline=None, derandomize=True)
    @given(st.data())
    def prop(data):
        SL, n, rng, B = random_sharded(data, st, False)
        N = D * SL.nv
        assert N < 2**24          # ids fit a bf16 mantissa trivially
        sources = rng.integers(0, n, B)
        fr = np.zeros((B, N), bool)
        fr[np.arange(B), sources] = True
        outs = {}
        for wire in (False, True):
            eng = DistEngine(SL, bfs_program(), mesh, mode="dc",
                             wire_bf16=wire)
            # uint32 monoid: the bf16 cast must never engage
            assert eng.wire_compressed is False
            parent = np.full((B, N), -1, np.int32)
            level = np.full((B, N), -1, np.int32)
            vid = np.broadcast_to(np.arange(N, dtype=np.uint32),
                                  (B, N)).copy()
            for i, s in enumerate(sources):
                parent[i, s] = s; level[i, s] = 0
            stb, _, _ = eng.run_batched(
                {"parent": parent, "level": level, "vid": vid}, fr)
            outs[wire] = {k: np.asarray(stb[k]) for k in ("parent",
                                                          "level")}
        for k in outs[False]:
            assert np.array_equal(outs[False][k], outs[True][k]), k
    prop()
    print("OK")
    """)


@pytest.mark.slow
def test_graph_server_dist_backed_matches_single_device():
    _run(COMMON + """
    from repro.graph import rmat
    from repro.apps.bfs import bfs
    from repro.serve import GraphQuery, GraphQueryServer

    g = rmat(8, 8, seed=11, weighted=True)
    L = build_layout(g, k=8, edge_tile=32, msg_tile=16)
    SL = shard_layout(L, D)
    srv = GraphQueryServer(L, mode="dc", sharded=SL, mesh=mesh,
                           wire_bf16=True)
    sources = [int(s) for s in np.linspace(0, g.n - 1, 12).astype(int)]
    for i, s in enumerate(sources):
        srv.submit(GraphQuery(i, "bfs", {"source": s}))
    srv.submit(GraphQuery(90, "sssp", {"source": sources[0]}))
    done = srv.run()
    assert len(done) == len(sources) + 1
    assert type(srv._engines["bfs"]).__name__ == "DistEngine"
    for q in done:
        if q.app != "bfs":
            continue
        seq = bfs(L, source=q.params["source"], backend="ref")
        assert np.array_equal(q.result["level"], seq["level"]), q.qid
        assert np.array_equal(q.result["parent"], seq["parent"]), q.qid
    print("OK")
    """)
