"""Model-stack correctness: every family forwards finite losses; SSD matches
its sequential oracle; MoE matches its token-loop oracle; chunked attention
matches naive; prefill+decode equals the full forward for all families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe as moe_lib
from repro.models.config import ModelConfig
from repro.models.layers import chunked_attention, rms_norm
from repro.models.ssm import ssd_chunked, ssd_sequential_ref
from repro.models.transformer import (backbone, embed_tokens, init_lm,
                                      lm_loss)
from repro.serve.engine import decode_step, init_cache, prefill

KEY = jax.random.PRNGKey(0)

CFGS = {
    "dense": ModelConfig(name="dense", family="dense", n_layers=3,
                         d_model=32, n_heads=4, n_kv=2, d_head=8, d_ff=64,
                         vocab=128, qkv_bias=True, dtype="float32"),
    "swa": ModelConfig(name="swa", family="dense", n_layers=2, d_model=32,
                       n_heads=4, n_kv=2, d_head=8, d_ff=64, vocab=128,
                       swa_window=6, dtype="float32"),
    "moe": ModelConfig(name="moe", family="moe", n_layers=2, d_model=32,
                       n_heads=4, n_kv=2, d_head=8, d_ff=0, vocab=128,
                       moe_experts=4, moe_top_k=2, moe_d_ff=48,
                       moe_shared_expert=True, moe_capacity=8.0,
                       dtype="float32"),
    "ssm": ModelConfig(name="ssm", family="ssm", n_layers=3, d_model=32,
                       n_heads=0, n_kv=0, d_head=0, d_ff=0, vocab=128,
                       ssm_state=8, ssm_head_dim=8, ssm_chunk=8,
                       dtype="float32"),
    "hybrid": ModelConfig(name="hybrid", family="hybrid", n_layers=4,
                          d_model=32, n_heads=4, n_kv=4, d_head=8, d_ff=64,
                          vocab=128, ssm_state=8, ssm_head_dim=8,
                          ssm_chunk=8, attn_every=2, dtype="float32"),
}


@pytest.mark.parametrize("name", list(CFGS))
def test_loss_finite(name, rng):
    cfg = CFGS[name]
    p, axes = init_lm(cfg, KEY)
    # every param leaf has a logical-axes annotation of matching rank
    flat_p = jax.tree_util.tree_flatten_with_path(p)[0]
    flat_a = jax.tree_util.tree_flatten_with_path(
        axes, is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))[0]
    assert len(flat_p) == len(flat_a)
    for (kp, leaf), (ka, ax) in zip(flat_p, flat_a):
        assert len(ax) == leaf.ndim, f"{kp}: {ax} vs {leaf.shape}"
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32))),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)))}
    loss = jax.jit(lambda p, b: lm_loss(p, cfg, b, dtype=jnp.float32))(p, b)
    assert jnp.isfinite(loss)
    assert 0 < float(loss) < 3 * np.log(cfg.vocab)


def test_ssd_matches_sequential(rng):
    B, L, H, P, N = 2, 24, 3, 4, 8
    x = jnp.asarray(rng.normal(size=(B, L, H, P)).astype(np.float32))
    dt = jnp.asarray(rng.random((B, L, H)).astype(np.float32) * 0.5)
    A = jnp.asarray(-rng.random(H).astype(np.float32))
    Bv = jnp.asarray(rng.normal(size=(B, L, N)).astype(np.float32))
    Cv = jnp.asarray(rng.normal(size=(B, L, N)).astype(np.float32))
    D = jnp.asarray(rng.random(H).astype(np.float32))
    h0 = jnp.asarray(rng.normal(size=(B, H, N, P)).astype(np.float32)) * .1
    for chunk in (1, 5, 8, 24, 32):
        y1, h1 = ssd_chunked(x, dt, A, Bv, Cv, D, chunk=chunk, h0=h0)
        y2, h2 = ssd_sequential_ref(x, dt, A, Bv, Cv, D, h0=h0)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                                   atol=1e-4)


def test_moe_matches_oracle(rng):
    cfg = CFGS["moe"]
    p, _ = moe_lib.moe_params(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(rng.normal(size=(2, 8, 32)).astype(np.float32))
    y = moe_lib.moe_fwd_dense(p, cfg, x, dtype=jnp.float32)
    yref = moe_lib.moe_ref(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), yref, atol=1e-5)


def test_moe_capacity_drops(rng):
    cfg = CFGS["moe"]
    cfg = ModelConfig(**{**cfg.__dict__, "moe_capacity": 0.25})
    p, _ = moe_lib.moe_params(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(rng.normal(size=(1, 16, 32)).astype(np.float32))
    y = moe_lib.moe_fwd_dense(p, cfg, x, dtype=jnp.float32)
    assert bool(jnp.isfinite(y).all())      # drops are no-ops, not NaNs


@pytest.mark.parametrize("window", [None, 5])
def test_chunked_attention_vs_naive(window, rng):
    q = jnp.asarray(rng.normal(size=(2, 13, 4, 8)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 13, 2, 8)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 13, 2, 8)).astype(np.float32))
    pos = np.arange(13)
    out = chunked_attention(q, k, v, causal=True, window=window,
                            q_chunk=4, kv_chunk=4)
    qg = np.asarray(q).reshape(2, 13, 2, 2, 8)
    s = np.einsum("bqkgd,bskd->bkgqs", qg, np.asarray(k)) / np.sqrt(8)
    mask = pos[:, None] >= pos[None, :]
    if window:
        mask &= pos[:, None] - pos[None, :] < window
    s = np.where(mask[None, None, None], s, -1e30)
    p_ = np.exp(s - s.max(-1, keepdims=True))
    p_ /= p_.sum(-1, keepdims=True)
    o = np.einsum("bkgqs,bskd->bqkgd", p_, np.asarray(v)).reshape(2, 13, 4, 8)
    np.testing.assert_allclose(np.asarray(out), o, atol=1e-5)


def test_encoder_attention_not_causal(rng):
    """hubert-style encoder: token t attends to t' > t."""
    cfg = ModelConfig(name="enc", family="audio", n_layers=1, d_model=16,
                      n_heads=2, n_kv=2, d_head=8, d_ff=32, vocab=16,
                      causal=False, frontend="frame", dtype="float32")
    p, _ = init_lm(cfg, KEY)
    e = jnp.asarray(rng.normal(size=(1, 8, 16)).astype(np.float32))
    from repro.models.transformer import embed_frontend
    h = embed_frontend(p, cfg, e, jnp.float32)
    out1 = backbone(p, cfg, h, jnp.arange(8), dtype=jnp.float32, remat=False)
    # perturb the LAST position; the FIRST position's output must change
    e2 = e.at[0, -1].add(1.0)
    h2 = embed_frontend(p, cfg, e2, jnp.float32)
    out2 = backbone(p, cfg, h2, jnp.arange(8), dtype=jnp.float32,
                    remat=False)
    assert float(jnp.abs(out1[0, 0] - out2[0, 0]).max()) > 1e-6


def _full_logits(p, cfg, toks):
    h = embed_tokens(p, cfg, toks, jnp.float32)
    x = backbone(p, cfg, h, jnp.arange(toks.shape[1]), dtype=jnp.float32,
                 remat=False)
    hh = rms_norm(x, p["final_norm"], cfg.norm_eps)
    return (hh @ p["embed"].astype(jnp.float32).T)


@pytest.mark.parametrize("name", list(CFGS))
def test_decode_equals_full_forward(name, rng):
    cfg = CFGS[name]
    S, extra = 12, 3
    p, _ = init_lm(cfg, KEY)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, S + extra))
                       .astype(np.int32))
    ref = _full_logits(p, cfg, toks)
    cache = init_cache(cfg, 2, 64, dtype=jnp.float32)
    lg, cache = prefill(p, cfg, {"tokens": toks[:, :S]}, cache,
                        dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ref[:, S - 1]),
                               atol=1e-4)
    for t in range(extra):
        lg, cache = decode_step(p, cfg, toks[:, S + t], cache,
                                dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(ref[:, S + t]), atol=1e-4)
