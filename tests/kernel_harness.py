"""Shared differential-testing harness for registry kernels.

Every property test of a registry kernel follows the same shape: draw a
message stream / graph geometry from closed hypothesis strategies, run
the kernel under test, run its pure-jnp oracle, and demand bit-exact
agreement.  The closed sets (monoidxdtype combos, segment counts, tile
and ``fold_q`` geometry, the over-cap ``NS_Q_PAIRS``) and the comparator
live HERE so ``test_fold_property.py``, ``test_fused_property.py``, and
future kernel tests draw from one vocabulary instead of copy-pasting it
per file.

Payloads are integer-valued (:func:`payload`) so even the f32 add fold
is exact regardless of summation order and every comparison can be
bit-for-bit.  Import order matters for the optional dev dependency: test
files must ``pytest.importorskip("hypothesis")`` BEFORE importing this
module (it imports hypothesis strategies at module scope).
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import strategies as st

from repro.core import monoid as M
from repro.kernels.fold_block import DEFAULT_FOLD_MAX_SEGMENTS

SEGMENT_OPS = {"add": jax.ops.segment_sum, "min": jax.ops.segment_min,
               "max": jax.ops.segment_max}

# the full Pallas-lowerable cross-product: {add,min,max} x {f32,i32,u32}
MONOIDS = {("add", "float32"): lambda: M.add(jnp.float32),
           ("add", "int32"): lambda: M.add(jnp.int32),
           ("add", "uint32"): lambda: M.add(jnp.uint32),
           ("min", "float32"): lambda: M.min_(jnp.float32),
           ("min", "int32"): lambda: M.min_(jnp.int32),
           ("min", "uint32"): lambda: M.min_(jnp.uint32),
           ("max", "float32"): lambda: M.max_(jnp.float32),
           ("max", "int32"): lambda: M.max_(jnp.int32),
           ("max", "uint32"): lambda: M.max_(jnp.uint32)}

# small closed sets keep the jit-compile count bounded while still covering
# multi-block streams, ragged tails, and the single-segment degenerate case
NUM_SEGMENTS = (1, 2, 5, 9, 17)
FOLD_TILES = (8, 16)

CAP = DEFAULT_FOLD_MAX_SEGMENTS
# closed (num_segments, fold_q) pairs keep the bucket grid small enough
# for interpret mode while covering: below / at / just past / 2x / 3x the
# cap, bucket widths that are non-powers-of-two, that don't divide the
# segment count, and that exceed it (single-bucket degenerate case)
NS_Q_PAIRS = ((8, 3), (100, 7), (1024, 2048), (CAP - 1, 512),
              (CAP, 1000), (CAP + 1, 257), (2 * CAP, 1024),
              (3 * CAP, 4096))


def payload(rng, n, dtype):
    """Integer-valued payload cast to ``dtype`` (nonnegative for unsigned):
    exact under any summation order, so f32 comparisons stay bit-exact."""
    lo = 0 if np.dtype(dtype).kind == "u" else -64
    return jnp.asarray(rng.integers(lo, 64, n).astype(np.dtype(dtype)))


def draw_monoid(data):
    """-> (name, dtype-string, Monoid) from the shared combo table."""
    name, dtype = data.draw(st.sampled_from(sorted(MONOIDS)))
    return name, dtype, MONOIDS[(name, dtype)]()


def draw_stream(data, ns, dtype, max_len=40):
    """Message stream for the fold contract: (vals, valid, ids).

    Duplicate + out-of-order ids by construction; ``ns - 1`` doubles as
    the engines' overflow bin and must behave like any other segment.
    The validity density is drawn from {0, 0.5, 1} so the all-invalid
    and all-valid extremes are first-class cases, not rare draws."""
    n = data.draw(st.integers(0, max_len))
    rng = np.random.default_rng(data.draw(st.integers(0, 10**6)))
    vals = payload(rng, n, dtype)
    valid = jnp.asarray(rng.random(n) < data.draw(
        st.sampled_from([0.0, 0.5, 1.0])))
    ids = jnp.asarray(rng.integers(0, ns, n).astype(np.int32))
    return vals, valid, ids


def draw_fused_case(data, ns, dtype, max_edges=60):
    """Graph-shaped inputs for the fused scatter->fold contract:
    (table, table_valid, idx, edge_valid, dst).

    The table plays the vertex message array; idx is the per-edge source
    slot (duplicates model high-degree sources), edge_valid the static
    structure, dst the destination segment.  Table-validity density and
    edge-validity density are drawn independently so empty frontiers
    (all table slots invalid) and all-pad tiles both occur."""
    m = data.draw(st.integers(1, 50))
    ne = data.draw(st.integers(0, max_edges))
    rng = np.random.default_rng(data.draw(st.integers(0, 10**6)))
    table = payload(rng, m, dtype)
    tvalid = jnp.asarray(
        rng.random(m) < data.draw(st.sampled_from([0.0, 0.5, 1.0])))
    idx = jnp.asarray(rng.integers(0, m, ne).astype(np.int32))
    evalid = jnp.asarray(
        rng.random(ne) < data.draw(st.sampled_from([0.0, 0.5, 1.0])))
    dst = jnp.asarray(rng.integers(0, ns, ne).astype(np.int32))
    return table, tvalid, idx, evalid, dst


def segment_oracle(mono, vals, valid, ids, ns):
    """The jax.ops ground truth of the fold contract: (acc, touched)."""
    mvals = jnp.where(valid, vals, mono.identity)
    acc = SEGMENT_OPS[mono.name](mvals, ids, num_segments=ns)
    touched = jax.ops.segment_max(valid.astype(jnp.int32), ids,
                                  num_segments=ns) > 0
    return acc, touched


def assert_kernel_equiv(kernel, ref_fn, args, ref_args=None):
    """Bit-exact differential check: ``kernel(*args)`` vs
    ``ref_fn(*(ref_args or args))``.

    Both sides return ``(acc, touched)`` (any tuple of arrays works);
    every component must match exactly — dtype-level exactness is the
    whole point of the integer payloads, so no tolerance parameter."""
    got = kernel(*args)
    want = ref_fn(*(args if ref_args is None else ref_args))
    if not isinstance(got, tuple):
        got, want = (got,), (want,)
    assert len(got) == len(want)
    for i, (g, w) in enumerate(zip(got, want)):
        g, w = np.asarray(g), np.asarray(w)
        assert g.shape == w.shape, (i, g.shape, w.shape)
        assert np.array_equal(g, w), (
            f"component {i} diverges: kernel={g!r} ref={w!r}")
