"""Sharding rules: logical-axis mapping, divisibility guards, overrides."""
import jax
import numpy as np
import pytest
from jax.sharding import AxisType, PartitionSpec as P

from repro.dist.sharding import (batch_spec, constrain, default_rules,
                                 set_activation_mesh, spec_for)


def _mesh(shape=(2, 2), axes=("data", "model")):
    # rule/spec tests need mesh *geometry* only; AbstractMesh avoids
    # requiring real devices in the single-device test process
    return jax.sharding.AbstractMesh(shape, axes)


def test_spec_for_basic():
    mesh = _mesh()
    rules = default_rules(mesh)
    # [vocab, embed] -> vocab on model, embed on data
    s = spec_for(("vocab", "embed"), (64, 32), mesh, rules)
    assert s == P("model", "data")


def test_divisibility_guard_replicates():
    mesh = _mesh((2, 16), ("data", "model"))
    rules = default_rules(mesh)
    # hubert: vocab=504 % 16 != 0 -> replicated
    s = spec_for(("vocab", "embed"), (504, 32), mesh, rules)
    assert s[0] is None
    # divisible dim still sharded
    s = spec_for(("vocab", "embed"), (512, 32), mesh, rules)
    assert s[0] == "model"


def test_axis_consumed_once():
    mesh = _mesh()
    rules = default_rules(mesh)
    # two model-mapped logical axes: only the first gets the mesh axis
    s = spec_for(("heads", "ff"), (8, 8), mesh, rules)
    assert s == P("model", None)


def test_overrides_via_config():
    import dataclasses
    from repro.configs import get_config
    mesh = _mesh()
    cfg = dataclasses.replace(
        get_config("qwen2-0.5b"),
        sharding_overrides=(("heads", None), ("kv", None)))
    rules = default_rules(mesh, cfg)
    assert rules["heads"] is None and rules["kv"] is None
    assert rules["ff"] == "model"


def test_multipod_fsdp_axes():
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    rules = default_rules(mesh)
    assert rules["embed"] == ("pod", "data")
    assert batch_spec(mesh) == P(("pod", "data"), None)


def test_constrain_guards():
    import jax.numpy as jnp
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    set_activation_mesh(mesh)
    try:
        # batch dim 1 CAN shard over extent-1 axes; guard never errors
        x = jnp.zeros((1, 8, 8))
        y = jax.jit(lambda x: constrain(x, "batch", None, "model"))(x)
        assert y.shape == x.shape
        x = jnp.zeros((4, 8, 8))
        y = jax.jit(lambda x: constrain(x, "batch", None, "model"))(x)
        assert y.shape == x.shape
    finally:
        set_activation_mesh(None)


def test_param_shardings_tree():
    from repro.dist.sharding import param_shardings
    from repro.models.transformer import init_lm
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("yi-6b")
    params, axes = init_lm(cfg, jax.random.PRNGKey(0))
    mesh = _mesh()
    sh = param_shardings(axes, params, mesh)
    flat_p = jax.tree_util.tree_leaves(params)
    flat_s = jax.tree_util.tree_leaves(
        sh, is_leaf=lambda x: hasattr(x, "spec"))
    assert len(flat_p) == len(flat_s)
    for p, s in zip(flat_p, flat_s):
        # every spec is applicable to its param
        assert len(s.spec) <= p.ndim


def test_param_shardings_device_put_roundtrip():
    """The rules layer's shardings apply for real: device_put on a concrete
    single-device mesh succeeds and values survive exactly."""
    from repro.dist.sharding import param_shardings
    from repro.models.transformer import init_lm
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("qwen2-0.5b")
    params, axes = init_lm(cfg, jax.random.PRNGKey(0))
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    sh = param_shardings(axes, params, mesh)
    placed = jax.tree_util.tree_map(jax.device_put, params, sh)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(placed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
