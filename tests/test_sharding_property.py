"""Properties of the repro.dist.sharding rules layer beyond the specs in
test_sharding.py: divisibility and consume-each-axis-once invariants over
arbitrary mesh geometries (hypothesis; whole module skips without it —
the device_put round trip lives in test_sharding.py so it always runs)."""
import jax
import numpy as np
import pytest

from repro.dist.sharding import default_rules, spec_for

hyp = pytest.importorskip("hypothesis")  # optional (requirements-dev.txt)
from hypothesis import given, settings, strategies as st  # noqa: E402

LOGICAL = ["vocab", "embed", "heads", "kv", "ff", "ssm_inner", "ssm_heads",
           "experts", "layers", None]

MESHES = st.sampled_from([
    ((2, 2), ("data", "model")),
    ((4, 2), ("data", "model")),
    ((2, 16), ("data", "model")),
    ((2, 2, 2), ("pod", "data", "model")),
    ((2, 4, 2), ("pod", "data", "model")),
    ((8,), ("dev",)),
    ((1, 1), ("data", "model")),
])


def _extent(mesh, entry):
    flat = (entry,) if isinstance(entry, str) else tuple(entry)
    return int(np.prod([mesh.shape[a] for a in flat])), flat


@settings(max_examples=200, deadline=None)
@given(st.data())
def test_spec_for_sharded_dims_always_divide(data):
    """No spec entry ever shards a dim that does not divide its mesh-axis
    extent, and no mesh axis is consumed twice in one spec."""
    shape_axes, names = data.draw(MESHES)
    mesh = jax.sharding.AbstractMesh(shape_axes, names)
    axes = tuple(data.draw(st.sampled_from(LOGICAL))
                 for _ in range(data.draw(st.integers(1, 4))))
    shape = tuple(data.draw(st.integers(1, 96)) for _ in axes)
    rules = default_rules(mesh)
    spec = spec_for(axes, shape, mesh, rules)
    assert len(spec) == len(shape)
    seen = set()
    for dim, entry in zip(shape, spec):
        if entry is None:
            continue
        extent, flat = _extent(mesh, entry)
        assert dim % extent == 0, (axes, shape, spec)
        assert not (seen & set(flat)), f"mesh axis consumed twice: {spec}"
        seen.update(flat)


@settings(max_examples=100, deadline=None)
@given(st.data())
def test_spec_for_respects_overrides(data):
    """An override to None always replicates that logical axis."""
    shape_axes, names = data.draw(MESHES)
    mesh = jax.sharding.AbstractMesh(shape_axes, names)
    victim = data.draw(st.sampled_from(
        [a for a in LOGICAL if a is not None]))

    class Cfg:
        sharding_overrides = ((victim, None),)

    rules = default_rules(mesh, Cfg())
    assert rules[victim] is None
    spec = spec_for((victim,), (64,), mesh, rules)
    assert spec[0] is None
