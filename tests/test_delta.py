"""Dynamic-graph deltas: DeltaBuffer bookkeeping, apply_delta exactness,
incremental recompute, and epoch-tagged serving with scoped invalidation.

The central contract under test (see repro/graph/delta.py):

  apply_delta(layout, delta) is BIT-IDENTICAL — every Layout field,
  dtype, shape and value — to build_layout(delta.edit_graph(g), ...)
  with the same partitioning and tile geometry.  Clean partitions'
  slices are reused verbatim; only dirty partitions relayout.

and the incremental-recompute contract (repro/core/engine.py):

  after an insertion-only delta, resuming a min-monoid fixpoint from
  the old converged state with the delta-touched vertices as frontier
  is exact (bit-exact labels/levels, <= 1e-6 for f32 distances);
  PageRank restarts from the old vector and reconverges to the same
  unique fixpoint in fewer sweeps.
"""
import dataclasses

import numpy as np
import pytest

from repro import obs
from repro.apps import (bfs_multi, bfs_seeded_multi, connected_components,
                        pagerank, sssp_multi)
from repro.graph import (DeltaBuffer, apply_delta, build_layout, from_edges,
                         grid2d, rmat, symmetrize)
from repro.obs import schema as obs_schema
from repro.serve import (DiskCache, GraphQuery, GraphQueryServer,
                         ServeConfig)
from repro.serve import cache as cache_lib


# ----------------------------------------------------------------------
# helpers
# ----------------------------------------------------------------------

def assert_layouts_identical(a, b):
    """Every field of the Layout dataclass: equal dtype, shape, value."""
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if va is None or isinstance(va, (int, bool, np.integer)):
            assert va == vb, f.name
            continue
        va, vb = np.asarray(va), np.asarray(vb)
        assert va.dtype == vb.dtype, f.name
        assert va.shape == vb.shape, f.name
        assert np.array_equal(va, vb), f.name


def _rand_graph(rng, n, weighted):
    m = int(rng.integers(0, 4 * n + 1))
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    w = rng.random(m).astype(np.float32) + 0.1 if weighted else None
    return from_edges(src, dst, n=n, weights=w)


def _rand_delta(rng, lay, n_ops, insert_only=False, weighted=None):
    weighted = lay.weighted if weighted is None else weighted
    d = DeltaBuffer.for_layout(lay)
    for _ in range(n_ops):
        u = int(rng.integers(0, lay.n))
        v = int(rng.integers(0, lay.n))
        if insert_only or rng.random() < 0.7:
            d.insert(u, v, float(rng.random() + 0.1) if weighted else None)
        else:
            d.delete(u, v)
    return d


def _sym_insert(d, rng, n, weighted):
    u, v = int(rng.integers(0, n)), int(rng.integers(0, n))
    w = float(rng.random() + 0.05) if weighted else None
    d.insert(u, v, w)
    d.insert(v, u, w)


@pytest.fixture(scope="module")
def sym_layout():
    g = symmetrize(rmat(8, 8, seed=3, weighted=True))
    return build_layout(g, k=8, edge_tile=64, msg_tile=32)


# ----------------------------------------------------------------------
# DeltaBuffer bookkeeping
# ----------------------------------------------------------------------

class TestDeltaBuffer:
    def test_bucketing_and_counts(self, sym_layout):
        d = DeltaBuffer.for_layout(sym_layout)
        assert not d and len(d) == 0 and d.insertions_only
        d.insert(0, 1, 2.0).insert(5, 200, 1.0).delete(3, 4)
        assert len(d) == 3 and bool(d)
        assert d.num_inserts == 2 and d.num_deletes == 1
        assert not d.insertions_only

    def test_last_op_wins(self, sym_layout):
        d = DeltaBuffer.for_layout(sym_layout)
        d.insert(0, 1, 2.0).delete(0, 1)
        assert len(d) == 1 and d.num_deletes == 1 and d.num_inserts == 0
        d.insert(0, 1, 7.0)                      # resurrect with new weight
        assert d.num_inserts == 1 and d.num_deletes == 0
        s, t, w = d.inserts()
        assert list(s) == [0] and list(t) == [1] and list(w) == [7.0]

    def test_partition_sets_and_touched(self, sym_layout):
        q, k, n = sym_layout.q, sym_layout.k, sym_layout.n
        u, v = 1, min(n - 1, 3 * q + 2)          # distinct partitions
        d = DeltaBuffer.for_layout(sym_layout).insert(u, v, 1.0)
        assert list(d.src_partitions()) == [u // q]
        assert list(d.dst_partitions()) == [v // q]
        assert list(d.dirty_partitions()) == sorted({u // q, v // q})
        t = d.touched()
        assert t.shape == (k * q,) and t.dtype == np.bool_
        assert set(np.nonzero(t)[0]) == {u, v}

    def test_id_validation(self, sym_layout):
        d = DeltaBuffer.for_layout(sym_layout)
        with pytest.raises(ValueError):
            d.insert(0, sym_layout.n)
        with pytest.raises(ValueError):
            d.delete(-1, 0)

    def test_edit_graph_reference_semantics(self):
        g = from_edges([0, 1, 2], [1, 2, 0], n=4,
                       weights=np.asarray([1., 2., 3.], np.float32))
        lay = build_layout(g, k=2, edge_tile=8, msg_tile=8)
        d = DeltaBuffer.for_layout(lay)
        d.delete(1, 2)                           # drop an edge
        d.insert(0, 1, 9.0)                      # overwrite a weight
        d.insert(3, 0, 4.0)                      # brand new edge
        g2 = d.edit_graph(g)
        pairs = {}
        src = np.repeat(np.arange(g2.n), g2.out_degrees())
        for s, t, w in zip(src, g2.indices, g2.weights):
            pairs[(int(s), int(t))] = float(w)
        assert pairs == {(0, 1): 9.0, (2, 0): 3.0, (3, 0): 4.0}


# ----------------------------------------------------------------------
# apply_delta == full rebuild, bit-exact
# ----------------------------------------------------------------------

class TestApplyDeltaExact:
    def _check(self, g, lay, d):
        inc = apply_delta(lay, d)
        full = build_layout(d.edit_graph(g), k=lay.k,
                           edge_tile=lay.edge_tile, msg_tile=lay.msg_tile,
                           fold_tile=lay.fold_tile, fold_q=lay.fold_q)
        assert_layouts_identical(inc, full)
        return inc

    def test_single_insert(self):
        g = from_edges([0, 1], [1, 2], n=6)
        lay = build_layout(g, k=2, edge_tile=8, msg_tile=8)
        d = DeltaBuffer.for_layout(lay).insert(4, 0)
        self._check(g, lay, d)

    def test_single_delete(self):
        g = from_edges([0, 1, 4], [1, 2, 0], n=6)
        lay = build_layout(g, k=2, edge_tile=8, msg_tile=8)
        d = DeltaBuffer.for_layout(lay).delete(1, 2)
        self._check(g, lay, d)

    def test_weight_overwrite(self):
        g = from_edges([0, 1], [1, 2], n=6,
                       weights=np.asarray([1., 2.], np.float32))
        lay = build_layout(g, k=2, edge_tile=8, msg_tile=8)
        d = DeltaBuffer.for_layout(lay).insert(0, 1, 5.0)
        inc = self._check(g, lay, d)
        assert inc.m == lay.m                    # no new edge, new weight

    def test_empty_delta_is_identity(self, sym_layout):
        d = DeltaBuffer.for_layout(sym_layout)
        assert_layouts_identical(apply_delta(sym_layout, d), sym_layout)

    def test_delete_only_edge_of_partition(self):
        g = from_edges([0, 5], [5, 0], n=8)
        lay = build_layout(g, k=4, edge_tile=8, msg_tile=8)
        d = DeltaBuffer.for_layout(lay).delete(5, 0)
        self._check(g, lay, d)

    def test_mismatched_partitioning_rejected(self, sym_layout):
        other = DeltaBuffer(k=sym_layout.k + 1, q=sym_layout.q,
                            n=sym_layout.n)
        with pytest.raises(ValueError):
            apply_delta(sym_layout, other)

    @pytest.mark.parametrize("weighted", [False, True])
    def test_randomized_mixed_deltas(self, weighted):
        rng = np.random.default_rng(11 + weighted)
        for trial in range(12):
            n = int(rng.integers(1, 60))
            g = _rand_graph(rng, n, weighted)
            k = int(rng.integers(1, 9))
            et = int(rng.choice([1, 4, 16]))
            mt = int(rng.choice([1, 2, 8]))
            lay = build_layout(g, k=k, edge_tile=et, msg_tile=mt)
            d = _rand_delta(rng, lay, int(rng.integers(1, 12)))
            self._check(g, lay, d)

    def test_dirty_set_matches_changed_partition_tags(self, sym_layout):
        """partition_tags flips exactly on delta.dirty_partitions() —
        the alignment the serve tier's scoped invalidation relies on."""
        rng = np.random.default_rng(7)
        d = _rand_delta(rng, sym_layout, 4, insert_only=True)
        new = apply_delta(sym_layout, d)
        old_t = cache_lib.partition_tags(sym_layout)
        new_t = cache_lib.partition_tags(new)
        changed = {p for p, (a, b) in enumerate(zip(old_t, new_t))
                   if a != b}
        assert changed <= set(d.dirty_partitions().tolist())
        # a genuinely new edge always flips its endpoint partitions
        assert changed


def test_apply_delta_property():
    """Hypothesis: random graph x random delta -> apply_delta bit-equals
    the full rebuild, and insert-only deltas keep CC resume exact."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**16), n=st.integers(1, 40),
           k=st.integers(1, 6), n_ops=st.integers(1, 8),
           weighted=st.booleans())
    def prop(seed, n, k, n_ops, weighted):
        rng = np.random.default_rng(seed)
        g = _rand_graph(rng, n, weighted)
        lay = build_layout(g, k=k, edge_tile=4, msg_tile=4)
        d = _rand_delta(rng, lay, n_ops)
        inc = apply_delta(lay, d)
        full = build_layout(d.edit_graph(g), k=lay.k,
                            edge_tile=lay.edge_tile,
                            msg_tile=lay.msg_tile,
                            fold_tile=lay.fold_tile, fold_q=lay.fold_q)
        assert_layouts_identical(inc, full)

    prop()


# ----------------------------------------------------------------------
# incremental recompute: resume == cold
# ----------------------------------------------------------------------

class TestIncrementalRecompute:
    @pytest.fixture(scope="class")
    def delta_pair(self):
        """(old layout, delta, new layout) with a symmetric insert-only
        delta, as an undirected dynamic-graph update would produce."""
        rng = np.random.default_rng(5)
        g = symmetrize(rmat(8, 8, seed=3, weighted=True))
        lay = build_layout(g, k=8, edge_tile=64, msg_tile=32)
        d = DeltaBuffer.for_layout(lay)
        for _ in range(6):
            _sym_insert(d, rng, g.n, weighted=True)
        return lay, d, apply_delta(lay, d)

    def test_cc_resume_bitexact_and_cheaper(self, delta_pair):
        lay, d, lay2 = delta_pair
        old = connected_components(lay)
        cold = connected_components(lay2)
        warm = connected_components(lay2, resume_labels=old["label"],
                                    touched=d.touched())
        assert np.array_equal(cold["label"], warm["label"])
        assert len(warm["stats"]) <= len(cold["stats"])

    def test_cc_resume_args_must_pair(self, sym_layout):
        with pytest.raises(ValueError):
            connected_components(sym_layout,
                                 resume_labels=np.zeros(4, np.uint32))

    def test_bfs_resume_bitexact(self, delta_pair):
        lay, d, lay2 = delta_pair
        s = 0
        old = bfs_multi(lay, [s])
        cold = bfs_multi(lay2, [s])
        lv = np.full((1, lay2.n_pad), -1, np.int64)
        par = np.full((1, lay2.n_pad), -1, np.int64)
        lv[0, :lay.n] = np.asarray(old["level"][0])
        par[0, :lay.n] = np.asarray(old["parent"][0])
        front = np.zeros((1, lay2.n_pad), bool)
        front[0, :lay2.n_pad] = d.touched()
        front[0, s] = True
        warm = bfs_seeded_multi(lay2, [s], seed_levels=lv,
                                seed_parents=par, frontiers=front)
        assert np.array_equal(np.asarray(cold["level"]),
                              np.asarray(warm["level"]))
        assert len(warm["stats"]) <= len(cold["stats"])

    def test_sssp_resume_bitexact(self, delta_pair):
        lay, d, lay2 = delta_pair
        s = 0
        old = sssp_multi(lay, [s])
        cold = sssp_multi(lay2, [s])
        dist0 = np.full((1, lay2.n_pad), np.inf, np.float32)
        dist0[0, :lay.n] = np.asarray(old["dist"][0], np.float32)
        warm = sssp_multi(lay2, [s], dist0=dist0,
                          frontier0=d.touched()[None].copy())
        assert np.array_equal(np.asarray(cold["dist"]),
                              np.asarray(warm["dist"]))
        assert len(warm["stats"]) <= len(cold["stats"])

    def test_pagerank_warm_restart_1e6(self, delta_pair):
        lay, _, lay2 = delta_pair
        ref = pagerank(lay2, iters=160)["pr"]
        old = pagerank(lay, iters=120)["pr"]
        warm = pagerank(lay2, iters=60, pr0=old)["pr"]
        assert np.abs(warm - ref).max() <= 1e-6

    def test_cc_resume_accepts_delta_buffer(self, delta_pair):
        """touched= takes the DeltaBuffer itself (preferred: the boolean
        mask cannot carry the insert/delete distinction the exactness
        contract depends on) — same bit-exact result as the mask path."""
        lay, d, lay2 = delta_pair
        old = connected_components(lay)
        cold = connected_components(lay2)
        warm = connected_components(lay2, resume_labels=old["label"],
                                    touched=d)
        assert np.array_equal(cold["label"], warm["label"])

    def test_resume_deletion_delta_raises(self, delta_pair):
        """Regression: a delta with deletions used to quietly recompute
        from the stale fixpoint (converging to a WRONG answer — deleted
        edges may require values to rise, which monotone relaxation
        cannot do).  It must raise instead, at both entry points."""
        lay, d, lay2 = delta_pair
        old = connected_components(lay)
        ddel = DeltaBuffer(k=d.k, q=d.q, n=d.n)
        u = 1
        ddel.insert(0, u, 1.0).delete(u, 0)
        assert ddel.num_deletes
        with pytest.raises(ValueError, match="insertion-only"):
            connected_components(lay2, resume_labels=old["label"],
                                 touched=ddel)
        from repro.apps.cc import cc_program
        from repro.core.engine import Engine
        import jax.numpy as jnp
        eng = Engine(lay2, cc_program(), mode="hybrid")
        with pytest.raises(ValueError, match="insertion-only"):
            eng.run(resume_from={"label": jnp.asarray(
                np.arange(lay2.n_pad, dtype=np.uint32))}, touched=ddel)

    def test_resume_non_idempotent_monoid_raises(self, delta_pair):
        """Regression: resuming an add-monoid program double-counts the
        contributions already absorbed into the old fixpoint — the engine
        must refuse and point at the residual path (pagerank pr0=)."""
        lay, d, lay2 = delta_pair
        from repro.apps.pagerank import pagerank_program
        from repro.core.engine import Engine
        import jax.numpy as jnp
        prog = pagerank_program(lay2.n)
        assert prog.monoid.name == "add"
        eng = Engine(lay2, prog, mode="dc")
        state = {"pr": jnp.zeros(lay2.n_pad, jnp.float32)}
        with pytest.raises(ValueError, match="idempotent"):
            eng.run(resume_from=state, touched=d.touched())


# ----------------------------------------------------------------------
# epoch-tagged serving: scoped invalidation + migration
# ----------------------------------------------------------------------

def _drain(srv, app, sources, qid0=0):
    for i, s in enumerate(sources):
        srv.submit(GraphQuery(qid=qid0 + i, app=app,
                              params={"source": int(s)}))
    srv.run()
    return {int(q.params["source"]): q.result for q in srv.done
            if q.app == app}


class TestEpochServing:
    def _delta_pair(self, insert_only=True, seed=5):
        rng = np.random.default_rng(seed)
        g = symmetrize(rmat(8, 8, seed=3, weighted=True))
        lay = build_layout(g, k=8, edge_tile=64, msg_tile=32)
        d = DeltaBuffer.for_layout(lay)
        for _ in range(4):
            _sym_insert(d, rng, g.n, weighted=True)
        if not insert_only:
            # delete a real symmetric pair so the delta stays applicable
            u = int(g.indices[0])
            d.delete(0, u)
            d.delete(u, 0)
        return lay, d, apply_delta(lay, d)

    def test_delta_swap_scoped_eviction_and_migration(self):
        lay, d, lay2 = self._delta_pair(insert_only=True)
        srv = GraphQueryServer(lay, ServeConfig(cache_size=64))
        _drain(srv, "sssp", [5, 9])
        old_tag = srv._layout_tag
        assert any(k.startswith(f"res|{old_tag}|")
                   for k in srv.cache.keys())
        changed = {p for p, (a, b) in enumerate(zip(
            cache_lib.partition_tags(lay), cache_lib.partition_tags(lay2)))
            if a != b}
        sem_clean = sem_dirty = 0
        for k in srv.cache.keys():
            if k.startswith(f"sem|{old_tag}|"):
                parts = set(np.asarray(
                    srv.cache.get(k)["parts"]).tolist())
                if parts & changed:
                    sem_dirty += 1
                else:
                    sem_clean += 1
        srv.swap_layout(lay2, delta=d)
        new_tag = srv._layout_tag
        assert srv.epoch == 1 and new_tag != old_tag
        # the old tag's namespace is fully garbage-collected
        assert not any(f"|{old_tag}|" in k for k in srv.cache.keys())
        # clean-partition landmarks were migrated to the new tag
        migrated = [k for k in srv.cache.keys()
                    if k.startswith(f"sem|{new_tag}|")]
        assert len(migrated) == sem_clean
        # a migrated landmark still seeds exactly: warm == cold
        if sem_clean:
            lms = srv.semantic.landmarks("sssp", {})
            assert lms
            warm = _drain(srv, "sssp", [77], qid0=50)
            ref = sssp_multi(lay2, [77])["dist"][0]
            fin = np.isfinite(ref)
            assert np.array_equal(np.isinf(warm[77]["dist"]),
                                  np.isinf(ref))
            assert np.abs(warm[77]["dist"][fin] - ref[fin]).max() <= 1e-6

    def test_deletion_delta_evicts_all_old_sem(self):
        lay, d, lay2 = self._delta_pair(insert_only=False)
        assert not d.insertions_only
        srv = GraphQueryServer(lay, ServeConfig(cache_size=64))
        _drain(srv, "sssp", [5, 9])
        old_tag = srv._layout_tag
        srv.swap_layout(lay2, delta=d)
        # deletions can raise distances: nothing migrates
        assert not any(f"|{old_tag}|" in k for k in srv.cache.keys())
        assert srv.semantic.landmarks("sssp", {}) == []

    def test_delta_swap_preserves_other_layouts(self, tmp_path):
        """Scoped GC only touches the OLD tag: a third layout's disk
        entries survive a delta swap between two other layouts."""
        lay, d, lay2 = self._delta_pair()
        other = build_layout(symmetrize(grid2d(8, 8, weighted=True,
                                               seed=0)),
                             k=4, edge_tile=64, msg_tile=32)
        path = str(tmp_path / "multi")
        srv_o = GraphQueryServer(other, ServeConfig(cache_backend=path,
                                                    cache_size=64))
        _drain(srv_o, "sssp", [3])
        other_keys = set(srv_o.cache.keys())
        srv = GraphQueryServer(lay, ServeConfig(cache_backend=path,
                                                cache_size=64))
        _drain(srv, "sssp", [5])
        srv.swap_layout(lay2, delta=d)
        assert other_keys <= set(srv.cache.keys())

    def test_delta_must_match_new_layout(self, sym_layout):
        srv = GraphQueryServer(sym_layout, ServeConfig())
        bad = DeltaBuffer(k=sym_layout.k + 1, q=sym_layout.q,
                          n=sym_layout.n)
        with pytest.raises(ValueError):
            srv.swap_layout(sym_layout, delta=bad)

    def test_swap_drains_queue_on_old_epoch(self):
        lay, d, lay2 = self._delta_pair()
        srv = GraphQueryServer(lay, ServeConfig())
        srv.submit(GraphQuery(qid=0, app="sssp", params={"source": 5}))
        ref = sssp_multi(lay, [5])["dist"][0]     # OLD layout's answer
        srv.swap_layout(lay2, delta=d)
        assert srv.epoch == 1 and not srv.queue
        done = {q.qid: q.result for q in srv.done}
        fin = np.isfinite(ref)
        assert np.array_equal(np.isinf(done[0]["dist"]), np.isinf(ref))
        assert np.abs(done[0]["dist"][fin] - ref[fin]).max() <= 1e-6

    def test_epoch_swap_event(self):
        lay, d, lay2 = self._delta_pair()
        with obs.override_enabled(True):
            obs.reset()
            srv = GraphQueryServer(lay, ServeConfig())
            _drain(srv, "sssp", [5])
            srv.swap_layout(lay2, delta=d)
            evs = obs.events("epoch_swap")
            assert evs and obs_schema.validate_event(evs[-1]) == []
            ev = evs[-1]
            assert ev["epoch"] == 1 and ev["delta"] is True
            assert ev["old"] != ev["new"]
            assert ev["changed_parts"] > 0
            assert ev["evicted"] + ev["migrated"] > 0
            srv.swap_layout(lay)                  # plain swap, no delta
            ev2 = obs.events("epoch_swap")[-1]
            assert ev2["epoch"] == 2 and ev2["delta"] is False
            assert ev2["evicted"] == 0 and ev2["migrated"] == 0
        obs.reset()

    def test_delta_apply_event(self, sym_layout):
        d = DeltaBuffer.for_layout(sym_layout).insert(0, 1, 1.0)
        with obs.override_enabled(True):
            obs.reset()
            apply_delta(sym_layout, d)
            evs = obs.events("delta_apply")
            assert evs and obs_schema.validate_event(evs[-1]) == []
            assert evs[-1]["inserts"] == 1 and evs[-1]["deletes"] == 0
            assert 0 < evs[-1]["dirty_parts"] <= sym_layout.k
        obs.reset()

    def test_close_the_loop_end_to_end(self, tmp_path):
        """The full dynamic-graph serving story: serve on epoch 0, apply
        a delta, swap with scoped invalidation, and verify epoch 1 serves
        exact answers on the NEW graph (migrated landmarks included)."""
        lay, d, lay2 = self._delta_pair()
        srv = GraphQueryServer(
            lay, ServeConfig(cache_backend=str(tmp_path / "e2e"),
                             cache_size=64))
        _drain(srv, "sssp", [5, 9])
        srv.swap_layout(lay2, delta=d)
        got = _drain(srv, "sssp", [5], qid0=40)
        ref = sssp_multi(lay2, [5])["dist"][0]    # cold truth, new graph
        fin = np.isfinite(ref)
        assert np.array_equal(np.isinf(got[5]["dist"]), np.isinf(ref))
        assert np.abs(got[5]["dist"][fin] - ref[fin]).max() <= 1e-6


# ----------------------------------------------------------------------
# symmetrize edge cases (satellite: d(u,v) == d(v,u) bit-exact)
# ----------------------------------------------------------------------

class TestSymmetrizeEdgeCases:
    def _pairs(self, g):
        src = np.repeat(np.arange(g.n, dtype=np.int64), g.out_degrees())
        w = g.weights if g.weights is not None else np.ones(g.m)
        return {(int(s), int(t)): float(x)
                for s, t, x in zip(src, g.indices, w)}

    def test_self_loop_with_weight_emitted_once(self):
        g = from_edges([2, 2, 0], [2, 2, 1], n=3,
                       weights=np.asarray([5.0, 3.0, 1.0], np.float32))
        gs = symmetrize(g)
        p = self._pairs(gs)
        assert p[(2, 2)] == 3.0                   # min of the duplicates
        assert p[(0, 1)] == 1.0 and p[(1, 0)] == 1.0
        assert gs.m == 3

    def test_antiparallel_unequal_weights_take_min(self):
        g = from_edges([0, 1], [1, 0], n=2,
                       weights=np.asarray([3.0, 1.0], np.float32))
        p = self._pairs(symmetrize(g))
        assert p == {(0, 1): 1.0, (1, 0): 1.0}

    def test_parallel_duplicates_deduplicated(self):
        g = from_edges([0, 0, 0], [1, 1, 1], n=2,
                       weights=np.asarray([4.0, 2.0, 8.0], np.float32))
        p = self._pairs(symmetrize(g))
        assert p == {(0, 1): 2.0, (1, 0): 2.0}

    def test_unweighted_duplicates_and_loops(self):
        g = from_edges([0, 0, 1, 2], [1, 1, 0, 2], n=3)
        gs = symmetrize(g)
        assert gs.weights is None
        assert set(self._pairs(gs)) == {(0, 1), (1, 0), (2, 2)}

    def test_empty_and_edgeless_graphs(self):
        ge = symmetrize(from_edges([], [], n=0))
        assert ge.n == 0 and ge.m == 0
        gn = symmetrize(from_edges([], [], n=5))
        assert gn.n == 5 and gn.m == 0

    def test_symmetric_distances_bitexact_post_layout(self):
        """d(u,v) == d(v,u) BIT-exact after symmetrize + build_layout:
        weights in eighths make every f32 path sum exact, so any
        asymmetry would be a real graph bug, not float noise."""
        rng = np.random.default_rng(0)
        m = 60
        src = rng.integers(0, 24, m)
        dst = rng.integers(0, 24, m)
        w = (rng.integers(1, 17, m) / 8.0).astype(np.float32)
        gs = symmetrize(from_edges(src, dst, n=24, weights=w))
        lay = build_layout(gs, k=4, edge_tile=16, msg_tile=8)
        sources = list(range(0, 24, 3))
        dist = np.asarray(sssp_multi(lay, sources)["dist"])
        for i, u in enumerate(sources):
            for j, v in enumerate(sources):
                assert dist[i][v] == dist[j][u], (u, v)
