"""Distributed serving regressions (in-process, D=1 mesh — a 1-device
all_to_all group is degenerate but runs the full wire path: pack, exchange,
unpack, fold):

  * odd-S ``wire_bf16`` crash — ``out_vals.reshape(D, S // 2, 2)`` blew up
    whenever the slot capacity was odd; the packed lane is now padded to
    even length and sliced back after the exchange.
  * ``_stats`` edge-degree overflow — ``astype(jnp.int64)`` silently means
    int32 with x64 off, wrapping active-degree sums past 2**31 and
    flipping the Eq. 1 mode decision.
  * wire helper roundtrips and the analytic wire-byte accounting.
  * :class:`repro.serve.GraphQueryServer` backed by a DistEngine drains
    same-signature queries into one fused distributed batch.

Multi-device parity lives in test_dist_serve_property.py (subprocess,
hypothesis, 4 virtual devices).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps.bfs import bfs, bfs_program
from repro.apps.sssp import sssp, sssp_program
from repro.dist.compat import AxisType, make_mesh
from repro.dist.engine import (DistEngine, _pack_bf16_pairs, _pack_bits,
                               _unpack_bf16_pairs, _unpack_bits,
                               dc_wire_bytes)
from repro.graph import build_layout, rmat
from repro.graph.shard import shard_layout


@pytest.fixture(scope="module")
def mesh1():
    return make_mesh((1,), ("dev",), axis_types=(AxisType.Auto,))


@pytest.fixture(scope="module")
def glayout():
    g = rmat(7, 8, seed=5, weighted=True)
    return build_layout(g, k=8, edge_tile=32, msg_tile=16)


def _widen_S_to_odd(SL):
    """Rebuild a ShardedLayout with S widened by one column (odd S).

    shard_layout pads S to a multiple of 8, so odd capacities never occur
    naturally — but nothing in the step contract forbids them, and the
    bf16 wire used to crash on them.  Widening is a pure re-index: slot
    flat indices move from ``sdev*S + pos`` to ``sdev*S2 + pos`` and the
    sentinel from ``D*S`` to ``D*S2``; the extra column is never valid."""
    D, S = SL.D, SL.S
    S2 = S + 1
    assert S2 % 2 == 1
    pad3 = ((0, 0), (0, 0), (0, 1))
    ms = SL.in_msg_slot.astype(np.int64)
    sdev, pos = ms // S, ms % S
    ms2 = np.where(ms == D * S, D * S2, sdev * S2 + pos).astype(np.int32)
    return dataclasses.replace(
        SL, S=S2,
        out_src_local=np.pad(SL.out_src_local, pad3),
        out_valid=np.pad(SL.out_valid, pad3),
        in_msg_slot=ms2)


def _sssp_state(n_pad, source):
    dist = np.full(n_pad, np.inf, np.float32)
    dist[source] = 0.0
    f = np.zeros(n_pad, bool)
    f[source] = True
    return {"dist": dist}, f


# ----------------------------------------------------------------------
# wire helpers
# ----------------------------------------------------------------------

@pytest.mark.parametrize("S", [1, 2, 7, 8, 16, 33])
def test_bf16_pair_pack_roundtrip(S):
    rng = np.random.default_rng(S)
    vals = jnp.asarray(rng.normal(size=(3, S)).astype(np.float32),
                       jnp.bfloat16)
    packed = _pack_bf16_pairs(vals, jnp.asarray(np.inf, jnp.bfloat16))
    assert packed.shape == (3, (S + 1) // 2) and packed.dtype == jnp.uint32
    out = _unpack_bf16_pairs(packed, S)
    assert out.shape == (3, S)
    assert np.array_equal(np.asarray(out, np.float32),
                          np.asarray(vals, np.float32))


@pytest.mark.parametrize("S", [1, 3, 8, 9, 24, 31])
def test_bitmap_pack_roundtrip(S):
    rng = np.random.default_rng(S)
    flags = jnp.asarray(rng.random((4, S)) < 0.5)
    packed = _pack_bits(flags)
    assert packed.shape == (4, -(-S // 8)) and packed.dtype == jnp.uint8
    assert np.array_equal(np.asarray(_unpack_bits(packed, S)),
                          np.asarray(flags))


def test_dc_wire_bytes_accounting():
    meta = dict(S=88, D=4)
    full = dc_wire_bytes(meta, 4, compressed=False, wire_bitmap=False)
    assert full == 4 * 88 * 4 + 4 * 88            # f32 values + bool flags
    bm = dc_wire_bytes(meta, 4, compressed=False, wire_bitmap=True)
    assert bm == 4 * 88 * 4 + 4 * 11              # flags 8x smaller
    both = dc_wire_bytes(meta, 4, compressed=True, wire_bitmap=True)
    assert both == 4 * 88 * 2 + 4 * 11            # values halved too
    assert dc_wire_bytes(meta, 4, compressed=True, wire_bitmap=True,
                         batch=8) == 8 * both
    # odd S pads one bf16 pair lane
    assert dc_wire_bytes(dict(S=9, D=2), 4, compressed=True,
                         wire_bitmap=True) == 2 * 10 * 2 + 2 * 2
    # dense_frontier ships no flags at all
    assert dc_wire_bytes(meta, 4, compressed=False, wire_bitmap=True,
                         dense_frontier=True) == 4 * 88 * 4


# ----------------------------------------------------------------------
# bugfix regressions
# ----------------------------------------------------------------------

def test_wire_bf16_odd_S_regression(glayout, mesh1):
    """wire_bf16 on an odd-S layout used to crash in
    ``out_vals.reshape(D, S // 2, 2)``; it must now run and agree with
    the even-S layout of the same graph bit-for-bit."""
    SL = shard_layout(glayout, 1)
    SLo = _widen_S_to_odd(SL)
    assert SLo.S % 2 == 1
    n_pad = SL.D * SL.nv
    state, frontier = _sssp_state(n_pad, 0)
    ref_eng = DistEngine(SL, sssp_program(), mesh1, mode="dc",
                         wire_bf16=True)
    odd_eng = DistEngine(SLo, sssp_program(), mesh1, mode="dc",
                         wire_bf16=True)
    assert ref_eng.wire_compressed and odd_eng.wire_compressed
    ref, _, _ = ref_eng.run(dict(state), frontier)
    odd, _, _ = odd_eng.run(dict(state), frontier)
    assert np.array_equal(np.asarray(ref["dist"]), np.asarray(odd["dist"]))
    # batched path over the odd-S layout too
    B = 4
    states = {"dist": np.full((B, n_pad), np.inf, np.float32)}
    fr = np.zeros((B, n_pad), bool)
    for i, s in enumerate(range(B)):
        states["dist"][i, s] = 0.0
        fr[i, s] = True
    stb, _, _ = odd_eng.run_batched(states, fr)
    st0, _, _ = odd_eng.run({"dist": states["dist"][0].copy()}, fr[0])
    assert np.array_equal(np.asarray(stb["dist"][0]),
                          np.asarray(st0["dist"]))


def test_stats_edge_sum_overflow_regression(glayout, mesh1):
    """Active edge-degree sums past 2**31 must not wrap: with x64 off the
    old ``astype(jnp.int64)`` silently accumulated in int32, went
    negative, and flipped the Eq. 1 decision toward SC."""
    SL = shard_layout(glayout, 1)
    n_pad = SL.D * SL.nv
    # every real vertex a 2**28-degree hub: the active sum is n * 2**28,
    # way past 2**31 yet exactly representable in f32 (powers of two)
    big = dataclasses.replace(
        SL, deg=np.full(n_pad, 2 ** 28, np.int64))
    eng = DistEngine(big, bfs_program(), mesh1, mode="hybrid")
    active = jnp.asarray(np.ones(n_pad, bool))
    n_act, e_act = eng._stats(active)
    expect = n_pad * 2 ** 28
    assert int(n_act) == n_pad
    assert float(e_act) == float(expect) and float(e_act) > 2 ** 31
    # the Eq. 1 threshold sees the true magnitude: a frontier this hot is
    # firmly DC territory, and a wrapped (negative) sum would say SC
    assert eng._choose_dc(float(e_act)) is True
    # per-partition stats take the same overflow-safe path
    counts, ea = eng._pstats(active)
    assert float(np.asarray(ea).sum()) == float(expect)


# ----------------------------------------------------------------------
# D=1 batched parity + dist-backed server
# ----------------------------------------------------------------------

def test_dist_run_batched_matches_sequential_d1(glayout, mesh1):
    SL = shard_layout(glayout, 1)
    n_pad = SL.D * SL.nv
    eng = DistEngine(SL, bfs_program(), mesh1, mode="dc")
    from repro.apps.bfs import bfs_multi
    sources = [0, 3, 9, 20]
    res = bfs_multi(glayout, sources, engine=eng)
    for i, s in enumerate(sources):
        seq = bfs(glayout, source=s, backend="ref")
        assert np.array_equal(res["level"][i], seq["level"]), s
        assert np.array_equal(res["parent"][i], seq["parent"]), s
    assert res["level"].shape == (len(sources), glayout.n)
    assert n_pad == glayout.n_pad


def test_graph_server_dist_backed(glayout, mesh1):
    """GraphQueryServer(sharded=, mesh=) answers batches through
    DistEngine.run_batched; results match the single-device reference and
    the LRU cache machinery is untouched."""
    from repro.serve import GraphQuery, GraphQueryServer
    SL = shard_layout(glayout, 1)
    calls = []
    orig = DistEngine.run_batched

    def spy(self, states, frontiers, **kw):
        calls.append(np.asarray(frontiers).shape[0])
        return orig(self, states, frontiers, **kw)

    DistEngine.run_batched = spy
    try:
        srv = GraphQueryServer(glayout, mode="dc", sharded=SL, mesh=mesh1)
        sources = [0, 2, 5, 11, 17]
        for i, s in enumerate(sources):
            srv.submit(GraphQuery(i, "bfs", {"source": s}))
        done = srv.run()
    finally:
        DistEngine.run_batched = orig
    assert len(done) == len(sources)
    assert calls == [8]                     # 5 sources pow2-padded to 8
    assert isinstance(srv._engines["bfs"], DistEngine)
    for q in done:
        seq = bfs(glayout, source=q.params["source"], backend="ref")
        assert np.array_equal(q.result["level"], seq["level"])
    # memoization still keyed on (layout identity, app, params)
    srv.submit(GraphQuery(99, "bfs", {"source": sources[0]}))
    srv.run()
    assert srv.cache_hits == 1


def test_graph_server_dist_requires_both_args(glayout, mesh1):
    from repro.serve import GraphQueryServer
    with pytest.raises(ValueError):
        GraphQueryServer(glayout, sharded=shard_layout(glayout, 1))
    with pytest.raises(ValueError):
        GraphQueryServer(glayout, mesh=mesh1)


def test_sharded_serving_disables_semantic_seeding(mesh1, monkeypatch):
    """The docstring promises semantic-cache seeding silently disables
    under ``sharded=`` serving; this asserts the disable actually
    happens — no ``sem|`` writes, no landmark lookup or capture, zero
    semantic hit/miss counters — while the SAME symmetric layout served
    unsharded does capture landmarks (so the contrast is the sharding,
    not the graph)."""
    from repro.apps.sssp import sssp
    from repro.graph import symmetrize
    from repro.serve import GraphQuery, GraphQueryServer, ServeConfig
    from repro.serve import cache as cache_lib

    g = symmetrize(rmat(7, 8, seed=3, weighted=True))
    lay = build_layout(g, k=8, edge_tile=32, msg_tile=16)

    # contrast leg first (before the tripwires): unsharded serving on
    # this layout is seedable and writes sem| landmark entries
    srv0 = GraphQueryServer(lay, ServeConfig(cache_size=64))
    assert srv0._seedable("sssp")
    for i, s in enumerate([3, 9]):
        srv0.submit(GraphQuery(i, "sssp", {"source": s}))
    srv0.run()
    assert any(k.startswith("sem|") for k in srv0.cache.keys())

    # sharded leg: semantic REQUESTED in the config, silently disabled
    SL = shard_layout(lay, 1)
    srv = GraphQueryServer(lay, ServeConfig(
        cache_size=64, mode="dc", sharded=SL, mesh=mesh1,
        semantic=True, capture_landmarks=True))
    assert srv.semantic is not None          # the cache client exists...
    assert not srv._seedable("sssp")         # ...but seeding is off
    monkeypatch.setattr(
        cache_lib.SemanticCache, "best_landmark",
        lambda *a, **k: pytest.fail("landmark lookup under sharded="))
    monkeypatch.setattr(
        GraphQueryServer, "_capture_landmarks",
        lambda *a, **k: pytest.fail("landmark capture under sharded="))
    sources = [3, 9, 14]
    for i, s in enumerate(sources):
        srv.submit(GraphQuery(10 + i, "sssp", {"source": s}))
    done = srv.run()
    assert len(done) == len(sources)
    assert srv.semantic_hits == 0 and srv.semantic_misses == 0
    assert not any("sem|" in k for k in srv.cache.keys())
    # and the un-seeded distributed answers are still exact
    for q in done:
        ref = sssp(lay, source=q.params["source"])["dist"]
        fin = np.isfinite(ref)
        assert np.array_equal(np.isinf(q.result["dist"]), np.isinf(ref))
        assert np.abs(q.result["dist"][fin] - ref[fin]).max() <= 1e-6
