"""Graph applications vs independent oracles (scipy/networkx), all engine
modes (hybrid / forced-SC / forced-DC) and the Pallas path."""
import numpy as np
import pytest
import scipy.sparse.csgraph as csg

from repro.apps import (bfs, connected_components, nibble, pagerank, sssp)
from repro.graph import build_layout, from_edges, grid2d, rmat, to_scipy


@pytest.fixture(scope="module")
def g_rmat():
    g = rmat(9, 8, seed=1)
    return g, build_layout(g, k=8, edge_tile=64, msg_tile=32)


@pytest.fixture(scope="module")
def g_weighted():
    g = rmat(9, 8, seed=2, weighted=True)
    return g, build_layout(g, k=8, edge_tile=64, msg_tile=32)


def _bfs_ref(g, src):
    d = csg.shortest_path(to_scipy(g), method="D", unweighted=True,
                          indices=src)
    return np.where(np.isinf(d), -1, d).astype(int)


@pytest.mark.parametrize("mode,pallas", [("hybrid", False), ("sc", False),
                                         ("dc", False), ("hybrid", True)])
def test_bfs(g_rmat, mode, pallas):
    g, L = g_rmat
    src = int(np.argmax(g.out_degrees()))
    res = bfs(L, source=src, mode=mode, use_pallas=pallas)
    assert np.array_equal(res["level"], _bfs_ref(g, src))
    # parents form a valid BFS tree: parent level = level - 1
    lv, par = res["level"], res["parent"]
    reached = (lv > 0)
    assert np.all(lv[par[reached]] == lv[reached] - 1)


def test_bfs_grid_large_diameter():
    g = grid2d(17, 13)
    L = build_layout(g, k=4, edge_tile=32, msg_tile=16)
    res = bfs(L, source=0)
    assert np.array_equal(res["level"], _bfs_ref(g, 0))


@pytest.mark.parametrize("mode,pallas", [("hybrid", False), ("sc", False),
                                         ("dc", False), ("hybrid", True)])
def test_sssp(g_weighted, mode, pallas):
    g, L = g_weighted
    src = int(np.argmax(g.out_degrees()))
    res = sssp(L, source=src, mode=mode, use_pallas=pallas)
    ref = csg.shortest_path(to_scipy(g), method="D", indices=src)
    fin = ~np.isinf(ref)
    assert np.array_equal(np.isinf(res["dist"]), ~fin)
    np.testing.assert_allclose(res["dist"][fin], ref[fin], atol=1e-5)


def _pr_ref(g, iters, d=0.85):
    x = np.full(g.n, 1.0 / g.n)
    P = to_scipy(g)
    outdeg = g.out_degrees()
    for _ in range(iters):
        c = np.where(outdeg > 0, x / np.maximum(outdeg, 1), 0.0)
        x = (1 - d) / g.n + d * (P.T @ c)
    return x


@pytest.mark.parametrize("fused", [True, False])
def test_pagerank(g_rmat, fused):
    g, L = g_rmat
    pr = pagerank(L, iters=10, fused=fused)["pr"]
    np.testing.assert_allclose(pr, _pr_ref(g, 10), atol=1e-6)


def test_pagerank_pallas(g_rmat):
    g, L = g_rmat
    pr = pagerank(L, iters=5, fused=False, use_pallas=True)["pr"]
    np.testing.assert_allclose(pr, _pr_ref(g, 5), atol=1e-5)


def test_connected_components(g_rmat):
    g, _ = g_rmat
    # symmetrize -> weakly connected components
    src = np.repeat(np.arange(g.n), g.out_degrees())
    gs = from_edges(np.concatenate([src, g.indices]),
                    np.concatenate([g.indices, src]), n=g.n, dedup=True)
    L = build_layout(gs, k=8, edge_tile=64, msg_tile=32)
    ours = connected_components(L)["label"]
    ncc, ref = csg.connected_components(to_scipy(gs), directed=False)
    for comp in range(ncc):
        assert len(np.unique(ours[ref == comp])) == 1
    assert len(np.unique(ours)) == ncc


def test_nibble_selective_continuity(g_rmat):
    """Nibble's defining properties (paper Alg. 3/4): probability mass is
    conserved below 1, support stays local, and initFunc keeps seeds active
    across iterations independent of gather updates."""
    g, L = g_rmat
    seed = int(np.argmax(g.out_degrees()))
    res = nibble(L, seeds=[seed], eps=1e-3, max_iters=30)
    pr = res["pr"]
    assert 0 < pr.sum() <= 1.0 + 1e-5
    assert pr[seed] > 0
    # support must be within the BFS-reachable set of the seed
    lv = _bfs_ref(g, seed)
    assert np.all(pr[lv < 0] == 0)


def test_nibble_work_efficiency(g_rmat):
    """Iterations touch ~local neighborhoods: modeled bytes stay far below
    one full-graph DC sweep (the paper's theoretical-efficiency claim)."""
    g, L = g_rmat
    seed = int(np.argmax(g.out_degrees()))
    res = nibble(L, seeds=[seed], eps=5e-3, max_iters=30, mode="hybrid")
    total = sum(s.dc_bytes + s.sc_bytes for s in res["stats"])
    full_sweep = float(L.dc_cost_bytes().sum())
    assert total < full_sweep
