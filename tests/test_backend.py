"""repro.backend: registry resolution, cross-backend parity, tuning cache,
and the engine-level rewiring (use_pallas alias, per-instance step cache)."""
import gc
import json
import sys
import warnings
import weakref
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps.bfs import bfs, bfs_program
from repro.backend import registry, tuning
from repro.core import monoid as M
from repro.core.engine import Engine
from repro.graph import build_layout, rmat

ON_TPU = jax.default_backend() == "tpu"
PARITY_BACKENDS = ["ref", "pallas-interpret"] + (["pallas-native"]
                                                 if ON_TPU else [])

MONOIDS = {
    ("add", "float32"): lambda: M.add(jnp.float32),
    ("add", "int32"): lambda: M.add(jnp.int32),
    ("min", "float32"): lambda: M.min_(jnp.float32),
    ("min", "int32"): lambda: M.min_(jnp.int32),
    ("max", "float32"): lambda: M.max_(jnp.float32),
    ("max", "int32"): lambda: M.max_(jnp.int32),
}


@pytest.fixture(scope="module")
def layout():
    g = rmat(7, 8, seed=11, weighted=False)
    return build_layout(g, k=4, edge_tile=32, msg_tile=16)


def _edge_vals(rng, L, dtype):
    # integer-valued payloads: add-folds are exact in f32, so every backend
    # must agree bit-for-bit regardless of fold order
    v = rng.integers(0, 64, L.num_edges)
    return jnp.asarray(v.astype(np.dtype(dtype)))


def _vertex_vals(rng, L, dtype):
    v = rng.integers(0, 64, L.n_pad)
    return jnp.asarray(v.astype(np.dtype(dtype)))


# ----------------------------------------------------------------------
# parity: ref / pallas-interpret / (TPU) pallas-native, bit-exact
# ----------------------------------------------------------------------

@pytest.mark.parametrize("dtype", ["float32", "int32"])
@pytest.mark.parametrize("monoid", ["add", "min", "max"])
@pytest.mark.parametrize("backend", PARITY_BACKENDS)
def test_gather_parity(layout, rng, backend, monoid, dtype):
    mono = MONOIDS[(monoid, dtype)]()
    b = registry.BACKENDS[backend]
    gk = b.gather(layout, mono)
    ref = registry.BACKENDS["ref"].gather(layout, mono)
    ev = _edge_vals(rng, layout, dtype)
    valid = jnp.asarray(layout.edge_valid) \
        & jnp.asarray(rng.random(layout.num_edges) < 0.7)
    pa = jnp.asarray((rng.random(layout.k) < 0.7).astype(np.int32))
    acc, touched = gk(ev, valid, pa)
    racc, rtouched = ref(ev, valid, pa)
    assert np.array_equal(np.asarray(touched), np.asarray(rtouched))
    assert np.array_equal(np.asarray(acc), np.asarray(racc))


@pytest.mark.parametrize("dtype", ["float32", "int32"])
@pytest.mark.parametrize("monoid", ["add", "min", "max"])
@pytest.mark.parametrize("backend", PARITY_BACKENDS)
def test_scatter_parity(layout, rng, backend, monoid, dtype):
    mono = MONOIDS[(monoid, dtype)]()
    b = registry.BACKENDS[backend]
    sk = b.scatter(layout, mono)
    ref = registry.BACKENDS["ref"].scatter(layout, mono)
    x = _vertex_vals(rng, layout, dtype)
    active = jnp.asarray(
        (rng.random(layout.n_pad) < 0.5).astype(np.int32))
    assert np.array_equal(np.asarray(sk(x, active)),
                          np.asarray(ref(x, active)))


@pytest.mark.parametrize("dtype", ["float32", "int32"])
@pytest.mark.parametrize("monoid", ["add", "min", "max"])
@pytest.mark.parametrize("backend", PARITY_BACKENDS)
def test_fold_parity(layout, rng, backend, monoid, dtype):
    """The blocked segmented fold agrees bit-for-bit with the ref fold on
    a realistic stream (the layout's gather-order edges, sentinel ids in
    the overflow bin) at every backend."""
    mono = MONOIDS[(monoid, dtype)]()
    ns = layout.n_pad + 1
    fold = registry.BACKENDS[backend].segment_fold(mono, tile=32)
    ref = registry.BACKENDS["ref"].segment_fold(mono)
    vals = _edge_vals(rng, layout, dtype)
    valid = jnp.asarray(layout.edge_valid) \
        & jnp.asarray(rng.random(layout.num_edges) < 0.7)
    ids = jnp.where(valid, jnp.asarray(layout.edge_dst), ns - 1)
    acc, touched = fold(vals, valid, ids, ns)
    racc, rtouched = ref(vals, valid, ids, ns)
    assert np.array_equal(np.asarray(touched), np.asarray(rtouched))
    assert np.array_equal(np.asarray(acc), np.asarray(racc))


def test_fold_default_is_pallas_on_cpu(monkeypatch):
    """Acceptance: kernel 'fold' resolves to a Pallas-backed kernel by
    default even on CPU hosts (interpret mode), while gather keeps ref.
    'Default' means no override: neutralize the env var (the CI kernels
    lane re-runs this module under both REPRO_KERNEL_BACKEND settings)."""
    monkeypatch.delenv(registry.ENV_VAR, raising=False)
    from repro.kernels.ops import FoldKernel
    b = registry.resolve("fold", "add", platform="cpu")
    assert b.name == "pallas-interpret"
    assert isinstance(b.segment_fold("add"), FoldKernel)
    assert registry.default_backend_name("cpu", kernel="fold") \
        == "pallas-interpret"
    assert registry.default_backend_name("cpu", kernel="gather") == "ref"
    assert registry.default_backend_name("tpu", kernel="fold") \
        == "pallas-native"


def test_fold_tile_knob(layout, rng, monkeypatch):
    """REPRO_FOLD_TILE steers the blocked fold's message tile; any valid
    tile produces identical results."""
    from repro.kernels import fold_block
    monkeypatch.setenv(fold_block.ENV_FOLD_TILE, "16")
    assert fold_block.default_fold_tile() == 16
    mono = MONOIDS[("add", "float32")]()
    fold = registry.BACKENDS["pallas-interpret"].segment_fold(mono)
    assert fold.tile is None                # resolved per call, from env
    ns = layout.n_pad + 1
    vals = _edge_vals(rng, layout, "float32")
    valid = jnp.asarray(layout.edge_valid)
    ids = jnp.where(valid, jnp.asarray(layout.edge_dst), ns - 1)
    acc16, _ = fold(vals, valid, ids, ns)
    monkeypatch.delenv(fold_block.ENV_FOLD_TILE)
    acc_def, _ = fold(vals, valid, ids, ns)
    assert np.array_equal(np.asarray(acc16), np.asarray(acc_def))


def test_fold_segment_cap_switches_to_two_level(layout, rng, monkeypatch):
    """Past REPRO_FOLD_MAX_SEGMENTS the flat one-hot block would outgrow
    VMEM; FoldKernel must switch to the two-level bucketed fold (same
    results, still a Pallas call — the old silent handoff to ref is
    gone)."""
    from repro.kernels import fold_block
    mono = MONOIDS[("add", "float32")]()
    fold = registry.BACKENDS["pallas-interpret"].segment_fold(mono)
    ns = layout.n_pad + 1
    vals = _edge_vals(rng, layout, "float32")
    valid = jnp.asarray(layout.edge_valid)
    ids = jnp.where(valid, jnp.asarray(layout.edge_dst), ns - 1)
    want = fold(vals, valid, ids, ns)
    monkeypatch.setenv(fold_block.ENV_FOLD_MAX_SEGMENTS, str(ns - 1))
    assert fold_block.max_fold_segments() == ns - 1

    import repro.kernels.ops as kops

    def boom(*a, **kw):
        raise AssertionError("flat blocked kernel ran past the segment cap")
    ran = {}
    two_level = kops.two_level_segment_fold

    def spy(*a, **kw):
        ran["two_level"] = True
        return two_level(*a, **kw)
    monkeypatch.setattr(kops, "blocked_segment_fold", boom)
    monkeypatch.setattr(kops, "two_level_segment_fold", spy)
    acc, touched = fold(vals, valid, ids, ns)
    assert ran.get("two_level"), "two-level fold did not run past the cap"
    assert np.array_equal(np.asarray(acc), np.asarray(want[0]))
    assert np.array_equal(np.asarray(touched), np.asarray(want[1]))
    # ... and RefFold is only reachable as the explicit 'ref' backend
    assert isinstance(registry.BACKENDS["ref"].segment_fold(mono),
                      kops.RefFold)


def test_fold_resolves_pallas_at_4x_cap(rng, monkeypatch):
    """Acceptance: for num_segments >= 4x the old 4096 cap the registry
    fold is still a Pallas kernel (two-level), bit-exact vs the ref
    fold."""
    from repro.kernels import fold_block
    monkeypatch.delenv(registry.ENV_VAR, raising=False)
    monkeypatch.delenv(fold_block.ENV_FOLD_MAX_SEGMENTS, raising=False)
    ns = 4 * fold_block.DEFAULT_FOLD_MAX_SEGMENTS + 13
    b = registry.resolve("fold", "add", platform="cpu")
    assert b.name == "pallas-interpret"
    mono = MONOIDS[("add", "int32")]()
    fold = b.segment_fold(mono)
    n = 3000
    vals = jnp.asarray(rng.integers(-64, 64, n).astype(np.int32))
    valid = jnp.asarray(rng.random(n) < 0.8)
    ids = jnp.asarray(np.sort(rng.integers(0, ns, n)).astype(np.int32))
    import repro.kernels.ops as kops

    def boom(*a, **kw):
        raise AssertionError("flat blocked kernel ran at 4x the cap")
    monkeypatch.setattr(kops, "blocked_segment_fold", boom)
    acc, touched = fold(vals, valid, ids, ns)
    racc, rtouched = registry.BACKENDS["ref"].segment_fold(mono)(
        vals, valid, ids, ns)
    assert np.array_equal(np.asarray(acc), np.asarray(racc))
    assert np.array_equal(np.asarray(touched), np.asarray(rtouched))


def test_fold_q_knob(layout, rng, monkeypatch):
    """REPRO_FOLD_Q steers the two-level fold's bucket width; any valid
    width (power of two or not) produces identical results."""
    from repro.kernels import fold_block, fold_two_level
    mono = MONOIDS[("add", "float32")]()
    fold = registry.BACKENDS["pallas-interpret"].segment_fold(mono)
    assert fold.q is None                   # resolved per call, from env
    ns = layout.n_pad + 1
    # force the two-level path on the module-scope layout's stream
    monkeypatch.setenv(fold_block.ENV_FOLD_MAX_SEGMENTS, str(ns - 1))
    vals = _edge_vals(rng, layout, "float32")
    valid = jnp.asarray(layout.edge_valid)
    ids = jnp.where(valid, jnp.asarray(layout.edge_dst), ns - 1)
    monkeypatch.setenv(fold_two_level.ENV_FOLD_Q, "24")
    assert fold_two_level.default_fold_q() == 24
    acc24, _ = fold(vals, valid, ids, ns)
    monkeypatch.setenv(fold_two_level.ENV_FOLD_Q, "37")
    acc37, _ = fold(vals, valid, ids, ns)
    monkeypatch.delenv(fold_two_level.ENV_FOLD_Q)
    acc_def, _ = fold(vals, valid, ids, ns)
    assert np.array_equal(np.asarray(acc24), np.asarray(acc37))
    assert np.array_equal(np.asarray(acc24), np.asarray(acc_def))


def test_layouts_carry_fold_q(monkeypatch):
    """build_layout resolves fold_q (REPRO_FOLD_Q > tuned/static geometry)
    and shard_layout propagates it, so both engines inherit the bucket
    width through the registry without further plumbing."""
    from repro.graph.shard import shard_layout
    from repro.kernels import fold_two_level
    # 'default' means no override: the CI kernels lane re-runs this module
    # under both REPRO_KERNEL_BACKEND settings, and under 'ref' the fold
    # below is a RefFold with no tile/q to carry
    monkeypatch.delenv(registry.ENV_VAR, raising=False)
    g = rmat(6, 8, seed=3)
    L = build_layout(g, k=4, edge_tile=32, msg_tile=16)
    assert L.fold_q == tuning.DEFAULT_GEOMETRY.fold_q
    monkeypatch.setenv(fold_two_level.ENV_FOLD_Q, "40")
    L2 = build_layout(g, k=4, edge_tile=32, msg_tile=16)
    assert L2.fold_q == 40
    assert shard_layout(L2, 2).fold_q == 40
    # explicit argument outranks the env knob
    L3 = build_layout(g, k=4, edge_tile=32, msg_tile=16, fold_q=64)
    assert L3.fold_q == 64
    # and make_kernels hands the layout's fold_q to the FoldKernel
    kset = registry.make_kernels(L3, MONOIDS[("add", "float32")]())
    assert kset.fold.q == 64
    # REPRO_FOLD_TILE steers layouts the same way (engines always pass
    # the layout's fold_tile, so the env must be honoured at build time)
    from repro.kernels import fold_block
    monkeypatch.setenv(fold_block.ENV_FOLD_TILE, "48")
    L4 = build_layout(g, k=4, edge_tile=32, msg_tile=16)
    assert L4.fold_tile == 48
    assert registry.make_kernels(L4, MONOIDS[("add", "float32")]()) \
        .fold.tile == 48


def test_stale_tuning_cache_is_a_miss(tmp_path):
    """A cache entry swept before a knob existed must read as a miss (so
    autotune re-sweeps) rather than pinning the new knob to its untuned
    default forever."""
    import json as _json
    g = rmat(6, 8, seed=2)
    geom = tuning.autotune(g, k=4, backend="ref", cache_dir=tmp_path,
                           reps=1)
    path = next(Path(tmp_path).glob("*.json"))
    rec = _json.loads(path.read_text())
    del rec["fold_q"]
    path.write_text(_json.dumps(rec))
    assert tuning.load_cached(g.n, g.m, 4, False, "cpu", "ref",
                              cache_dir=tmp_path) is None
    # ... and a fresh autotune() re-sweeps and restores a complete entry
    geom2 = tuning.autotune(g, k=4, backend="ref", cache_dir=tmp_path,
                            reps=1)
    rec2 = _json.loads(path.read_text())
    assert rec2["fold_q"] == geom2.fold_q


@pytest.mark.parametrize("backend", PARITY_BACKENDS)
def test_spmv_parity(layout, rng, backend):
    b = registry.BACKENDS[backend]
    vk = b.spmv(layout)
    ref = registry.BACKENDS["ref"].spmv(layout)
    x = _vertex_vals(rng, layout, "float32")
    assert np.array_equal(np.asarray(vk(x)), np.asarray(ref(x)))


def test_gather_int32_above_2_24(layout, rng):
    """min/max/add over int32 state beyond the f32 mantissa must round-trip
    exactly (the one-hot MXU path used to truncate through float32)."""
    big = (1 << 24) + rng.integers(1, 1000, layout.num_edges)
    ev = jnp.asarray(big.astype(np.int32))
    valid = jnp.asarray(layout.edge_valid) \
        & jnp.asarray(rng.random(layout.num_edges) < 0.05)
    pa = jnp.ones((layout.k,), jnp.int32)
    for name in ("min", "max", "add"):
        mono = MONOIDS[(name, "int32")]()
        gk = registry.BACKENDS["pallas-interpret"].gather(layout, mono)
        acc, touched = gk(ev, valid, pa)
        racc, rtouched = registry.BACKENDS["ref"].gather(layout, mono)(
            ev, valid, pa)
        assert np.array_equal(np.asarray(acc), np.asarray(racc)), name
        # and the surviving values really are the un-truncated payloads
        tm = np.asarray(touched)
        if name in ("min", "max") and tm.any():
            assert (np.asarray(acc)[tm] > (1 << 24)).all()


def test_scatter_int32_above_2_24(layout, rng):
    mono = MONOIDS[("min", "int32")]()
    sk = registry.BACKENDS["pallas-interpret"].scatter(layout, mono)
    big = (1 << 24) + rng.integers(1, 1000, layout.n_pad)
    x = jnp.asarray(big.astype(np.int32))
    active = jnp.ones((layout.n_pad,), jnp.int32)
    msg = np.asarray(sk(x, active))
    real = np.asarray(layout.png_src) < layout.n_pad
    assert (msg[real] > (1 << 24)).all()


# ----------------------------------------------------------------------
# registry: selection, env override, unsupported-combo fallback
# ----------------------------------------------------------------------

def test_env_override_selects_backend(monkeypatch):
    monkeypatch.setenv(registry.ENV_VAR, "pallas-interpret")
    assert registry.default_backend_name("cpu") == "pallas-interpret"
    monkeypatch.setenv(registry.ENV_VAR, "ref")
    assert registry.default_backend_name("cpu") == "ref"
    monkeypatch.setenv(registry.ENV_VAR, "no-such-backend")
    with pytest.raises(ValueError, match="no-such-backend"):
        registry.default_backend_name("cpu")


@pytest.mark.parametrize("env", ["ref", "pallas-interpret"])
def test_env_override_end_to_end(layout, monkeypatch, env):
    monkeypatch.setenv(registry.ENV_VAR, env)
    eng = Engine(layout, bfs_program())
    # the override steers every kernel, including the fused DC step (bfs
    # is min/uint32, which both Pallas backends and ref lower)
    assert eng.backend_names == {"gather": env, "scatter": env,
                                 "fold": env, "fused_dc": env}
    res = bfs(layout, source=3, engine=eng)
    ref = bfs(layout, source=3, backend="ref")
    assert np.array_equal(res["level"], ref["level"])
    assert np.array_equal(res["parent"], ref["parent"])


def test_unsupported_combo_falls_back_to_ref(layout):
    # pallas-native cannot lower on a CPU host -> per-call ref fallback
    if ON_TPU:
        pytest.skip("fallback path is the non-TPU behaviour")
    with pytest.warns(RuntimeWarning, match="falling back to 'ref'"):
        b = registry.resolve("gather", "add", jnp.float32,
                             choice="pallas-native")
    assert b.name == "ref"
    # a monoid outside the Pallas set falls back even for pallas-interpret
    with pytest.warns(RuntimeWarning, match="min_with_payload"):
        b = registry.resolve("gather", M.min_with_payload(),
                             choice="pallas-interpret")
    assert b.name == "ref"
    # ... and the registry view agrees
    assert registry.supported("cpu", "gather", "min_with_payload",
                              jnp.uint64) == ("ref",)
    with pytest.raises(ValueError, match="unknown backend"):
        registry.resolve("gather", "add", choice="cuda")


def test_supported_matrix():
    assert set(registry.supported("cpu", "gather", "add", jnp.float32)) \
        == {"ref", "pallas-interpret"}
    assert set(registry.supported("tpu", "gather", "add", jnp.float32)) \
        == {"ref", "pallas-interpret", "pallas-native"}
    assert set(registry.supported("cpu", "fold", "add", jnp.float32)) \
        == {"ref", "pallas-interpret"}
    assert set(registry.supported("tpu", "fold", "min", jnp.uint32)) \
        == {"ref", "pallas-interpret", "pallas-native"}
    # packed uint64 folds stay ref-only (no 8-byte Pallas lowering)
    assert registry.supported("cpu", "fold", "min_with_payload",
                              jnp.uint64) == ("ref",)
    # spmv is an add/float kernel on every backend
    assert registry.supported("cpu", "spmv", "min", jnp.float32) == ()


# ----------------------------------------------------------------------
# engine rewiring: use_pallas alias, per-instance step cache
# ----------------------------------------------------------------------

def test_use_pallas_alias_matches_backend(layout):
    with pytest.deprecated_call():
        old = bfs(layout, source=3, use_pallas=True)
    new = bfs(layout, source=3, backend="pallas-interpret")
    assert np.array_equal(old["level"], new["level"])
    assert np.array_equal(old["parent"], new["parent"])


def test_step_cache_is_per_instance(layout):
    assert not hasattr(Engine._step_fn, "cache_info"), \
        "lru_cache on a method pins self (layout arrays) process-wide"
    eng = Engine(layout, bfs_program(), backend="ref")
    fn = eng._step_fn(0, 0)
    assert eng._step_fn(0, 0) is fn and (0, 0) in eng._step_cache
    other = Engine(layout, bfs_program(), backend="ref")
    assert other._step_cache == {}          # cache is not shared
    ref = weakref.ref(eng)
    del eng, fn
    gc.collect()
    assert ref() is None, "engine must be collectable once dropped"


# ----------------------------------------------------------------------
# tuning: sweep, disk cache, layout feedback
# ----------------------------------------------------------------------

def test_autotune_caches_and_feeds_layout(tmp_path, monkeypatch):
    g = rmat(6, 8, seed=2)
    geom = tuning.autotune(g, k=4, backend="ref", cache_dir=tmp_path,
                           reps=1)
    files = list(Path(tmp_path).glob("*.json"))
    assert len(files) == 1
    rec = json.loads(files[0].read_text())
    assert rec["edge_tile"] == geom.edge_tile
    assert rec["msg_tile"] == geom.msg_tile
    assert rec["fold_q"] == geom.fold_q
    assert len(rec["sweep"]) == len(tuning.candidates())
    assert all("fold_q" in s for s in rec["sweep"])
    # second call is a cache hit (sweep entries unchanged on disk)
    assert tuning.autotune(g, k=4, backend="ref",
                           cache_dir=tmp_path) == geom
    # build_layout with tiles unset resolves through the same cache
    monkeypatch.setenv(tuning.ENV_DIR, str(tmp_path))
    monkeypatch.setenv(registry.ENV_VAR, "ref")
    L = build_layout(g, k=4)
    assert (L.edge_tile, L.msg_tile) == (geom.edge_tile, geom.msg_tile)
    L2 = tuning.tuned_layout(g, k=4, backend="ref", cache_dir=tmp_path)
    assert (L2.edge_tile, L2.msg_tile) == (geom.edge_tile, geom.msg_tile)


def test_resolve_geometry_default_without_cache(tmp_path):
    assert tuning.resolve_geometry(100, 800, 8, cache_dir=tmp_path) \
        == tuning.DEFAULT_GEOMETRY


# ----------------------------------------------------------------------
# serving tier + benchmark harness ride the same registry
# ----------------------------------------------------------------------

def test_graph_query_server(layout):
    from repro.serve import GraphQuery, GraphQueryServer
    srv = GraphQueryServer(layout, backend="ref")
    srv.submit(GraphQuery(0, "bfs", {"source": 0}))
    srv.submit(GraphQuery(1, "bfs", {"source": 5}))
    srv.submit(GraphQuery(2, "pagerank", {"iters": 3}))
    done = srv.run()
    assert [q.qid for q in done] == [0, 1, 2]
    assert np.array_equal(done[1].result["level"],
                          bfs(layout, source=5)["level"])
    assert list(srv._engines) == ["bfs"]    # one shared engine, two queries


def test_graph_query_server_per_query_overrides(layout):
    """mode/backend in params bypass the shared engine instead of being
    silently dropped or colliding with the explicit kwargs."""
    from repro.apps.pagerank import pagerank
    from repro.serve import GraphQuery, GraphQueryServer
    srv = GraphQueryServer(layout, backend="ref")
    srv.submit(GraphQuery(0, "bfs", {"source": 0, "mode": "dc"}))
    srv.submit(GraphQuery(1, "pagerank", {"iters": 3, "mode": "dc",
                                          "backend": "pallas-interpret"}))
    srv.submit(GraphQuery(2, "cc", {"mode": "sc"}))
    done = srv.run()
    assert srv._engines == {}               # every query overrode the mode
    assert np.array_equal(done[0].result["level"],
                          bfs(layout, source=0)["level"])
    np.testing.assert_allclose(done[1].result["pr"],
                               pagerank(layout, iters=3)["pr"], rtol=1e-6)
    assert done[2].result["label"] is not None


def test_check_bench_regression(tmp_path):
    import importlib.util
    path = Path(__file__).resolve().parents[1] / "tools" \
        / "check_bench_regression.py"
    spec = importlib.util.spec_from_file_location("check_bench", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)

    # the guard must cover the over-cap two-level fold rows (fold2) and
    # the fused DC step rows the same way it covers every other kernel row
    kernels = ("gather", "scatter", "spmv", "fold", "fold2", "fused")

    def doc(walls):
        return {"results": [
            {"kernel": k, "backend": "ref", "monoid": "add", "scale": 6,
             "wall_s": w} for k, w in zip(kernels, walls)]}
    flat = doc([0.010] * 6)
    assert mod.check(flat, flat, 2.0, 0.005) == 0
    # one kernel 3x while the rest hold: a real regression — including
    # when the regressed row is the two-level fold or the fused step
    assert mod.check(doc([0.030, 0.010, 0.010, 0.010, 0.010, 0.010]),
                     flat, 2.0, 0.005) == 1
    assert mod.check(doc([0.010, 0.010, 0.010, 0.010, 0.030, 0.010]),
                     flat, 2.0, 0.005) == 1
    assert mod.check(doc([0.010, 0.010, 0.010, 0.010, 0.010, 0.030]),
                     flat, 2.0, 0.005) == 1
    # two of six kernels ~4x: the healthy rows must outvote them (a
    # median calibration would forgive this as 'machine speed')
    assert mod.check(doc([0.039, 0.039, 0.010, 0.010, 0.010, 0.010]),
                     flat, 2.0, 0.005) == 1
    # a uniformly 2.5x slower runner is machine speed, not a regression
    assert mod.check(doc([0.025] * 6), flat, 2.0, 0.005) == 0
    # ... but a uniform slowdown beyond the calibration clamp still fails
    assert mod.check(doc([0.080] * 6), flat, 2.0, 0.005) == 1
    # sub-floor rows are dispatch jitter and never flag
    assert mod.check(doc([0.004] * 6), doc([0.001] * 6), 2.0, 0.005) == 0
    other = {"results": [{"kernel": "spmv", "backend": "ref",
                          "monoid": "add", "scale": 8, "wall_s": 1.0}]}
    assert mod.check(flat, other, 2.0, 0.005) == 2              # no overlap


def test_bench_kernels_smoke(tmp_path):
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    try:
        from benchmarks import bench_kernels
    finally:
        sys.path.pop(0)
    out = tmp_path / "BENCH_kernels.json"
    doc = bench_kernels.run(scales=[6], backends=["ref", "pallas-interpret"],
                            reps=1, k=4, out_path=out)
    disk = json.loads(out.read_text())
    assert disk == doc
    assert disk["meta"]["platform"] == jax.default_backend()
    rows = disk["results"]
    assert {r["kernel"] for r in rows} == {"gather", "scatter", "spmv",
                                           "fold", "fold2", "fused"}
    assert {r["backend"] for r in rows} == {"ref", "pallas-interpret"}
    assert all(r["wall_s"] > 0 for r in rows)
    assert all(r["fold_q"] > 0 for r in rows)
