"""Per-kernel interpret-mode validation: shape/dtype sweeps vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.graph import build_layout, rmat, uniform_random
from repro.kernels import ops as kops
from repro.kernels import ref as kref


def _layout(seed=3, weighted=True, k=8, et=64, mt=32, scale=8):
    g = rmat(scale, 8, seed=seed, weighted=weighted)
    return g, build_layout(g, k=k, edge_tile=et, msg_tile=mt)


@pytest.mark.parametrize("monoid,dtype", [
    ("add", jnp.float32), ("min", jnp.uint32), ("min", jnp.float32),
    ("max", jnp.uint32), ("max", jnp.float32), ("add", jnp.uint32),
])
def test_segment_combine_sweep(monoid, dtype, rng):
    g, L = _layout()
    gk = kops.GatherKernel(L, monoid, dtype, interpret=True)
    if jnp.issubdtype(dtype, jnp.floating):
        ev = jnp.asarray(rng.random(L.num_edges).astype(np.float32))
    else:
        ev = jnp.asarray(rng.integers(0, 1000, L.num_edges).astype(np.uint32))
    valid = jnp.asarray(L.edge_valid) & jnp.asarray(rng.random(L.num_edges) < 0.7)
    pa = jnp.ones(L.k, jnp.int32)
    acc, touched = gk(ev, valid, pa)
    racc, rtouch = kref.segment_combine_ref(
        ev, valid, jnp.asarray(L.edge_dst), L.n_pad + 1, monoid)
    racc, rtouch = racc[:L.n_pad], rtouch[:L.n_pad]
    assert bool((touched == rtouch).all())
    if monoid == "add":
        np.testing.assert_allclose(np.asarray(acc)[np.asarray(touched)],
                                   np.asarray(racc)[np.asarray(rtouch)],
                                   rtol=1e-5, atol=1e-5)
    else:
        m = np.asarray(touched)
        assert np.array_equal(np.asarray(acc)[m], np.asarray(racc)[m])


def test_segment_combine_partition_predication(rng):
    """Tiles of inactive source partitions are skipped (2-level active list):
    the result must equal a fold over only the active partitions' edges."""
    g, L = _layout()
    gk = kops.GatherKernel(L, "add", jnp.float32, interpret=True)
    ev = jnp.asarray(rng.random(L.num_edges).astype(np.float32))
    valid = jnp.asarray(L.edge_valid)
    pa = np.zeros(L.k, np.int32)
    pa[::2] = 1                                 # only even partitions active
    acc, touched = gk(ev, valid, jnp.asarray(pa))
    keep = pa[L.tile_src_part.repeat(L.edge_tile)] > 0
    racc, rtouch = kref.segment_combine_ref(
        ev, valid & jnp.asarray(keep), jnp.asarray(L.edge_dst),
        L.n_pad + 1, "add")
    m = np.asarray(touched)
    assert bool((touched == rtouch[:L.n_pad]).all())
    np.testing.assert_allclose(np.asarray(acc)[m],
                               np.asarray(racc[:L.n_pad])[m], rtol=1e-5)


@pytest.mark.parametrize("seed,k,et,mt", [(1, 4, 16, 8), (2, 8, 64, 32),
                                          (5, 8, 128, 128)])
def test_spmv_block_sweep(seed, k, et, mt, rng):
    g, L = _layout(seed=seed, k=k, et=et, mt=mt)
    sk = kops.SpmvKernel(L, interpret=True)
    x = jnp.asarray(rng.random(L.n_pad).astype(np.float32))
    y = sk(x)
    yref = kref.spmv_block_ref(
        x, jnp.asarray(L.msg_slot), jnp.asarray(L.png_src),
        jnp.asarray(L.edge_dst), jnp.asarray(L.edge_valid),
        jnp.asarray(L.edge_w) if L.edge_w is not None else None, L.n_pad)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                               rtol=1e-4, atol=1e-5)


def test_spmv_unweighted(rng):
    g, L = _layout(weighted=False)
    sk = kops.SpmvKernel(L, interpret=True)
    x = jnp.asarray(rng.random(L.n_pad).astype(np.float32))
    y = sk(x)
    yref = kref.spmv_block_ref(
        x, jnp.asarray(L.msg_slot), jnp.asarray(L.png_src),
        jnp.asarray(L.edge_dst), jnp.asarray(L.edge_valid), None, L.n_pad)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), rtol=1e-4,
                               atol=1e-5)


@pytest.mark.parametrize("monoid,dtype", [("add", jnp.float32),
                                          ("min", jnp.uint32)])
def test_dc_gather_sweep(monoid, dtype, rng):
    g, L = _layout()
    sk = kops.ScatterKernel(L, monoid, dtype, interpret=True)
    if jnp.issubdtype(dtype, jnp.floating):
        x = jnp.asarray(rng.random(L.n_pad).astype(np.float32))
    else:
        x = jnp.asarray(rng.integers(0, 99, L.n_pad).astype(np.uint32))
    active = jnp.asarray(rng.random(L.n_pad) < 0.4)
    msg = sk(x, active)
    ref = kref.dc_gather_ref(x, active, jnp.asarray(L.png_src),
                             jnp.asarray((L.png_src < L.n_pad)), monoid)
    assert np.array_equal(np.asarray(msg), np.asarray(ref))
