"""Layout construction invariants + hypothesis round-trip properties."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.graph import (build_layout, from_edges, grid2d, ring, rmat, star,
                         uniform_random)
from repro.core.cost import CostModel


def _check_layout(g, L):
    # gather tiles are destination-major (paper: read bin[:][p'] columns)
    assert np.all(np.diff(L.tile_dst_part) >= 0)
    assert L.tile_first.sum() == len(np.unique(L.tile_dst_part))
    v = L.edge_valid
    assert v.sum() == g.m
    # (src, dst) multiset reconstructed from the dc_bin layout
    gsrc = L.png_src[L.msg_slot[v]]
    recon = sorted(zip(gsrc.tolist(), L.edge_dst[v].tolist()))
    orig = sorted(zip(np.repeat(np.arange(g.n), g.out_degrees()).tolist(),
                      g.indices.tolist()))
    assert recon == orig
    # local ids consistent with tile partition metadata
    sp = L.tile_src_part.repeat(L.edge_tile)[v]
    dp = L.tile_dst_part.repeat(L.edge_tile)[v]
    assert np.all(gsrc == sp * L.q + L.edge_src_local[v])
    assert np.all(L.edge_dst[v] == dp * L.q + L.edge_dst_local[v])
    # PNG slots: one per unique (src, dst-partition) pair
    real_slots = L.png_src < L.n_pad
    pairs = set()
    for s, d in zip(gsrc.tolist(), (L.edge_dst[v] // L.q).tolist()):
        pairs.add((s, d))
    assert real_slots.sum() == len(pairs)
    # per-partition Eq.1 constants
    assert L.part_edges.sum() == g.m
    assert L.part_msgs.sum() == real_slots.sum()


@pytest.mark.parametrize("maker", [
    lambda: rmat(8, 8, seed=1),
    lambda: uniform_random(100, 700, seed=2),
    lambda: ring(37),
    lambda: star(50),
    lambda: grid2d(9, 7),
])
@pytest.mark.parametrize("k", [1, 4, 8])
def test_layout_invariants(maker, k):
    g = maker()
    L = build_layout(g, k=k, edge_tile=16, msg_tile=8)
    _check_layout(g, L)


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_layout_roundtrip_random(data):
    n = data.draw(st.integers(2, 60))
    m = data.draw(st.integers(1, 300))
    seed = data.draw(st.integers(0, 10_000))
    rng = np.random.default_rng(seed)
    g = from_edges(rng.integers(0, n, m), rng.integers(0, n, m), n=n,
                   dedup=True)
    k = data.draw(st.sampled_from([1, 2, 4, 7]))
    L = build_layout(g, k=min(k, n), edge_tile=8, msg_tile=8)
    _check_layout(g, L)


def test_cost_model_mode_choice():
    g = rmat(8, 8, seed=3)
    L = build_layout(g, k=8, edge_tile=16, msg_tile=8)
    cm = CostModel.from_layout(L)
    k = L.k
    # no active edges anywhere -> nothing runs DC
    none = cm.choose_dc(np.zeros(k), np.zeros(k, bool))
    assert not none.any()
    # everything active -> dense partitions choose DC (paper: PageRank)
    all_dc = cm.choose_dc(L.part_edges, L.part_edges > 0)
    assert all_dc[L.part_edges > 0].all()
    b = cm.bytes_for(all_dc, L.part_edges, L.part_edges > 0)
    assert b["sc_bytes"] == 0 and b["dc_bytes"] > 0
