"""Serving: continuous batching equals single-stream decoding; SWA ring
buffer; SSM/hybrid state caches; batched multi-source graph-query
scheduling (fused run_batched batches, pow2 padding, LRU memoization,
dedicated-engine isolation)."""
import collections

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import (bfs, bfs_multi, sssp, sssp_multi,
                        sssp_parents_multi, sssp_with_parents)
from repro.core.engine import Engine
from repro.graph import build_layout, rmat
from repro.models.config import ModelConfig
from repro.models.transformer import init_lm
from repro.serve import GraphQuery, GraphQueryServer, Request, Server
from repro.serve.engine import decode_step, init_cache, prefill

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv=2, d_head=16, d_ff=128, vocab=256,
                  dtype="float32")


def _single(params, cfg, prompt, n, max_len=64):
    c = init_cache(cfg, 1, max_len, jnp.float32)
    lg, c = prefill(params, cfg,
                    {"tokens": jnp.asarray(prompt)[None]}, c,
                    dtype=jnp.float32)
    out = [int(jnp.argmax(lg[0]))]
    for _ in range(n - 1):
        lg, c = decode_step(params, cfg, jnp.asarray([out[-1]], jnp.int32),
                            c, dtype=jnp.float32)
        out.append(int(jnp.argmax(lg[0])))
    return out


def test_continuous_batching_matches_single_stream():
    params, _ = init_lm(CFG, jax.random.PRNGKey(0))
    srv = Server(params, CFG, n_slots=2, max_len=64, dtype=jnp.float32)
    prompts = [np.arange(5, dtype=np.int32) + r for r in range(3)]
    for r, pr in enumerate(prompts):
        srv.submit(Request(rid=r, prompt=pr, max_new=6))
    done = srv.run()
    assert len(done) == 3
    for d in done:
        # max_new=6 decode steps + the prefill token = 7 tokens
        assert d.out == _single(params, CFG, prompts[d.rid], 7)


def test_max_new_counts_decode_steps_not_prefill_token():
    """Regression: the prefill-produced token used to count toward
    max_new, so every request decoded one step fewer than asked."""
    params, _ = init_lm(CFG, jax.random.PRNGKey(0))
    prompt = np.arange(5, dtype=np.int32)
    for max_new in (0, 1, 3):
        srv = Server(params, CFG, n_slots=1, max_len=64, dtype=jnp.float32)
        srv.submit(Request(rid=0, prompt=prompt, max_new=max_new))
        done = srv.run()
        assert len(done) == 1
        # prefill token + exactly max_new decode steps
        assert len(done[0].out) == max_new + 1
        assert done[0].out == _single(params, CFG, prompt, max_new + 1)


def test_slot_reuse():
    params, _ = init_lm(CFG, jax.random.PRNGKey(0))
    srv = Server(params, CFG, n_slots=1, max_len=64, dtype=jnp.float32)
    for r in range(3):
        srv.submit(Request(rid=r, prompt=np.arange(4, dtype=np.int32) + r,
                           max_new=3))
    done = srv.run()
    assert sorted(d.rid for d in done) == [0, 1, 2]


def test_swa_ring_buffer_decode():
    """With window W, decoding past W positions must equal the full forward
    (which masks beyond the window) - the rolling cache is lossless."""
    cfg = ModelConfig(name="swa", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv=2, d_head=8, d_ff=64, vocab=64,
                      swa_window=6, dtype="float32")
    params, _ = init_lm(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    S, extra = 5, 8                       # decode well past the window
    toks = jnp.asarray(rng.integers(0, 64, (1, S + extra)).astype(np.int32))
    from repro.models.transformer import backbone, embed_tokens
    from repro.models.layers import rms_norm
    h = embed_tokens(params, cfg, toks, jnp.float32)
    x = backbone(params, cfg, h, jnp.arange(S + extra), dtype=jnp.float32,
                 remat=False)
    ref = rms_norm(x, params["final_norm"], cfg.norm_eps) @ \
        params["embed"].astype(jnp.float32).T
    cache = init_cache(cfg, 1, 64, jnp.float32)
    assert cache["k"].shape[2] == 6       # ring buffer = window
    lg, cache = prefill(params, cfg, {"tokens": toks[:, :S]}, cache,
                        dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ref[:, S - 1]),
                               atol=1e-4)
    for t in range(extra):
        lg, cache = decode_step(params, cfg, toks[:, S + t], cache,
                                dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(ref[:, S + t]), atol=1e-4)


@pytest.mark.parametrize("family", ["ssm", "hybrid", "moe"])
def test_server_other_families(family):
    cfgs = {
        "ssm": ModelConfig(name="s", family="ssm", n_layers=2, d_model=32,
                           n_heads=0, n_kv=0, d_head=0, d_ff=0, vocab=64,
                           ssm_state=8, ssm_head_dim=8, ssm_chunk=8,
                           dtype="float32"),
        "hybrid": ModelConfig(name="h", family="hybrid", n_layers=2,
                              d_model=32, n_heads=4, n_kv=4, d_head=8,
                              d_ff=64, vocab=64, ssm_state=8,
                              ssm_head_dim=8, ssm_chunk=8, attn_every=2,
                              dtype="float32"),
        "moe": ModelConfig(name="m", family="moe", n_layers=2, d_model=32,
                           n_heads=4, n_kv=2, d_head=8, d_ff=0, vocab=64,
                           moe_experts=4, moe_top_k=2, moe_d_ff=48,
                           moe_capacity=8.0, dtype="float32"),
    }
    cfg = cfgs[family]
    params, _ = init_lm(cfg, jax.random.PRNGKey(2))
    srv = Server(params, cfg, n_slots=2, max_len=32, dtype=jnp.float32)
    prompts = [np.arange(4, dtype=np.int32) + r for r in range(2)]
    for r, pr in enumerate(prompts):
        srv.submit(Request(rid=r, prompt=pr, max_new=4))
    done = srv.run()
    assert len(done) == 2
    for d in done:
        # max_new=4 decode steps + the prefill token = 5 tokens
        assert d.out == _single(params, cfg, prompts[d.rid], 5, max_len=32)


# ----------------------------------------------------------------------
# graph-analytics serving: batched multi-source execution
# ----------------------------------------------------------------------

GRAPH_BACKENDS = ("ref", "pallas-interpret")


@pytest.fixture(scope="module")
def glayout():
    g = rmat(8, 8, seed=3, weighted=True)
    return build_layout(g, k=8, edge_tile=64, msg_tile=32)


def _sources(layout, b):
    """b distinct sources spread over the degree distribution."""
    return [int(s) for s in
            np.linspace(0, layout.n - 1, b).astype(np.int64)]


@pytest.mark.parametrize("backend", GRAPH_BACKENDS)
def test_bfs_multi_bitexact_16_sources(glayout, backend):
    """>=16 sources in ONE fused run_batched invocation, bit-exact with
    the corresponding sequential per-query results."""
    sources = _sources(glayout, 16)
    res = bfs_multi(glayout, sources, backend=backend)
    assert res["level"].shape == (16, glayout.n)
    for i, s in enumerate(sources):
        seq = bfs(glayout, source=s, backend=backend)
        assert np.array_equal(res["level"][i], seq["level"]), s
        assert np.array_equal(res["parent"][i], seq["parent"]), s


@pytest.mark.parametrize("backend", GRAPH_BACKENDS)
def test_sssp_multi_bitexact_16_sources(glayout, backend):
    sources = _sources(glayout, 16)
    res = sssp_multi(glayout, sources, backend=backend)
    assert res["dist"].shape == (16, glayout.n)
    for i, s in enumerate(sources):
        seq = sssp(glayout, source=s, backend=backend)
        assert np.array_equal(res["dist"][i], seq["dist"]), s


def test_sssp_parents_multi_matches_sequential(glayout):
    sources = _sources(glayout, 4)
    res = sssp_parents_multi(glayout, sources)
    for i, s in enumerate(sources):
        seq = sssp_with_parents(glayout, source=s)
        assert np.array_equal(res["dist"][i], seq["dist"]), s
        assert np.array_equal(res["parent"][i], seq["parent"]), s


def test_run_batched_freezes_converged_lanes(glayout):
    """A lane whose frontier drains early must keep its final state while
    other lanes continue (per-query done masks + lane compaction)."""
    from repro.apps.bfs import bfs_program
    eng = Engine(glayout, bfs_program(), mode="dc", backend="ref")
    # lane 0: an isolated-ish low-degree source; lane 1: high-degree hub
    deg = glayout.deg
    lo = int(np.argmin(deg[:glayout.n]))
    hi = int(np.argmax(deg[:glayout.n]))
    res = bfs_multi(glayout, [lo, hi], engine=eng)
    for i, s in enumerate((lo, hi)):
        seq = bfs(glayout, source=s, backend="ref")
        assert np.array_equal(res["level"][i], seq["level"])


def test_graph_server_batches_queue_into_one_invocation(glayout, monkeypatch):
    """step() drains all compatible queries into ONE fused run_batched
    call; non-batchable queries keep their own path."""
    calls = []
    orig = Engine.run_batched

    def spy(self, states, frontiers, **kw):
        calls.append(np.asarray(frontiers).shape[0])
        return orig(self, states, frontiers, **kw)

    monkeypatch.setattr(Engine, "run_batched", spy)
    srv = GraphQueryServer(glayout, backend="ref")
    sources = _sources(glayout, 16)
    for i, s in enumerate(sources):
        srv.submit(GraphQuery(i, "bfs", {"source": s}))
    srv.submit(GraphQuery(90, "pagerank", {"iters": 3}))
    srv.submit(GraphQuery(91, "sssp", {"source": sources[0]}))
    done = srv.run()
    assert len(done) == 18
    assert calls == [16, 1]          # one fused bfs batch + one sssp batch
    assert list(srv._engines) == ["bfs", "sssp"]
    for q in done:
        if q.app == "bfs":
            seq = bfs(glayout, source=q.params["source"], backend="ref")
            assert np.array_equal(q.result["level"], seq["level"])


def test_graph_server_pads_batches_to_pow2(glayout):
    """5 distinct sources -> an 8-lane engine invocation: the per-batch-
    size jit cache stays logarithmic in the queue depth."""
    srv = GraphQueryServer(glayout, backend="ref")
    for i, s in enumerate(_sources(glayout, 5)):
        srv.submit(GraphQuery(i, "bfs", {"source": s}))
    srv.run()
    eng = srv._engines["bfs"]
    assert ("batched", 8) in eng._step_cache
    assert not any(k == ("batched", 5) for k in eng._step_cache)


def test_graph_server_queue_is_deque_and_batch_aware(glayout):
    srv = GraphQueryServer(glayout, backend="ref")
    assert isinstance(srv.queue, collections.deque)
    s = _sources(glayout, 3)
    srv.submit(GraphQuery(0, "bfs", {"source": s[0]}))
    srv.submit(GraphQuery(1, "pagerank", {"iters": 2}))
    srv.submit(GraphQuery(2, "bfs", {"source": s[1]}))
    # one tick answers BOTH bfs queries (batch-aware, not FIFO-single)
    assert srv.step()
    assert sorted(q.qid for q in srv.done) == [0, 2]
    assert [q.qid for q in srv.queue] == [1]
    srv.run()
    assert sorted(q.qid for q in srv.done) == [0, 1, 2]


def test_graph_server_lru_result_cache(glayout):
    srv = GraphQueryServer(glayout, backend="ref", cache_size=2)
    s = _sources(glayout, 3)
    srv.submit(GraphQuery(0, "bfs", {"source": s[0]}))
    srv.run()
    assert (srv.cache_hits, srv.cache_misses) == (0, 1)
    # repeated (app, params) -> served from cache, same result object
    srv.submit(GraphQuery(1, "bfs", {"source": s[0]}))
    srv.run()
    assert (srv.cache_hits, srv.cache_misses) == (1, 1)
    assert srv.done[1].result is srv.done[0].result
    # eviction: cache_size=2, three distinct queries -> oldest evicted
    srv.submit(GraphQuery(2, "bfs", {"source": s[1]}))
    srv.submit(GraphQuery(3, "bfs", {"source": s[2]}))
    srv.run()
    srv.submit(GraphQuery(4, "bfs", {"source": s[0]}))   # evicted: rerun
    srv.run()
    assert srv.cache_misses == 4
    # clear_cache() empties the backend (the invalidation rule is
    # specified once, on the CacheBackend protocol)
    srv.clear_cache()
    assert len(srv.cache) == 0


def test_graph_server_dedicated_engine_does_not_poison_cache(glayout):
    """Queries overriding mode/backend/bw_ratio run on a dedicated engine;
    the shared engine survives untouched (identity-asserted) and a
    subsequent plain query reuses it."""
    srv = GraphQueryServer(glayout, backend="ref")
    srv.submit(GraphQuery(0, "bfs", {"source": 0}))
    srv.run()
    eng = srv._engines["bfs"]
    srv.submit(GraphQuery(1, "bfs", {"source": 1, "mode": "sc"}))
    srv.submit(GraphQuery(2, "bfs", {"source": 2, "bw_ratio": 9.0}))
    srv.submit(GraphQuery(3, "bfs", {"source": 3,
                                     "backend": "pallas-interpret"}))
    done = srv.run()
    assert srv._engines == {"bfs": eng}      # no poisoning, no new entries
    for q in done[1:]:
        seq = bfs(glayout, source=q.params["source"])
        assert np.array_equal(q.result["level"], seq["level"])
    # a subsequent plain query reuses the shared engine (identity)
    srv.submit(GraphQuery(4, "bfs", {"source": 4}))
    srv.run()
    assert srv._engines["bfs"] is eng


def test_graph_server_single_path_only_kwargs_skip_batching(glayout):
    """Params outside the *_multi signature (use_pallas here) must route
    to the single-query path instead of crashing the fused batch."""
    srv = GraphQueryServer(glayout, backend="ref")
    s = _sources(glayout, 2)
    srv.submit(GraphQuery(0, "bfs", {"source": s[0], "use_pallas": False}))
    srv.submit(GraphQuery(1, "bfs", {"source": s[1]}))
    done = srv.run()
    assert len(done) == 2
    for q in done:
        seq = bfs(glayout, source=q.params["source"])
        assert np.array_equal(q.result["level"], seq["level"])


def test_graph_server_unhashable_params_skip_cache(glayout):
    """nibble's seeds list is canonicalized to a tuple and cached; a
    genuinely unhashable param just skips memoization."""
    srv = GraphQueryServer(glayout, backend="ref")
    srv.submit(GraphQuery(0, "nibble", {"seeds": [0, 1]}))
    srv.submit(GraphQuery(1, "nibble", {"seeds": [0, 1]}))
    srv.run()
    assert srv.cache_hits == 1               # list params canonicalized
    assert srv._result_key(GraphQuery(9, "nibble",
                                      {"seeds": {0: 1}})) is None


def test_bench_serve_smoke(tmp_path):
    """The serving benchmark emits schema-compatible rows (CI artifact)."""
    from benchmarks.bench_serve import run as bench_run
    out = tmp_path / "BENCH_serve.json"
    doc = bench_run([6], ["ref"], [1, 2], reps=1, k=8, out_path=out)
    rows = doc["results"]
    assert rows and out.exists()
    for r in rows:
        assert {"kernel", "backend", "monoid", "scale", "wall_s",
                "batch", "qps"} <= r.keys()
        assert r["wall_s"] > 0 and r["qps"] > 0
    kernels = {r["kernel"] for r in rows}
    assert "serve_bfs_batched_b2" in kernels
    assert "serve_sssp_seq_b1" in kernels
    # semantic-cache sweep: warmed repeat-source traffic must beat the
    # cold server by a wide margin (the headroom is ~25x; 1.5x is the
    # acceptance floor with room for CI noise)
    wall = {r["kernel"]: r["wall_s"] for r in rows}
    for app in ("bfs", "sssp"):
        for b in (1, 2):
            cold = wall[f"serve_{app}_cold_b{b}"]
            warmed = wall[f"serve_{app}_warmed_b{b}"]
            assert cold >= 1.5 * warmed, (app, b, cold, warmed)
