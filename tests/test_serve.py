"""Serving: continuous batching equals single-stream decoding; SWA ring
buffer; SSM/hybrid state caches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.transformer import init_lm
from repro.serve import Request, Server
from repro.serve.engine import decode_step, init_cache, prefill

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv=2, d_head=16, d_ff=128, vocab=256,
                  dtype="float32")


def _single(params, cfg, prompt, n, max_len=64):
    c = init_cache(cfg, 1, max_len, jnp.float32)
    lg, c = prefill(params, cfg,
                    {"tokens": jnp.asarray(prompt)[None]}, c,
                    dtype=jnp.float32)
    out = [int(jnp.argmax(lg[0]))]
    for _ in range(n - 1):
        lg, c = decode_step(params, cfg, jnp.asarray([out[-1]], jnp.int32),
                            c, dtype=jnp.float32)
        out.append(int(jnp.argmax(lg[0])))
    return out


def test_continuous_batching_matches_single_stream():
    params, _ = init_lm(CFG, jax.random.PRNGKey(0))
    srv = Server(params, CFG, n_slots=2, max_len=64, dtype=jnp.float32)
    prompts = [np.arange(5, dtype=np.int32) + r for r in range(3)]
    for r, pr in enumerate(prompts):
        srv.submit(Request(rid=r, prompt=pr, max_new=6))
    done = srv.run()
    assert len(done) == 3
    for d in done:
        assert d.out == _single(params, CFG, prompts[d.rid], 6)


def test_slot_reuse():
    params, _ = init_lm(CFG, jax.random.PRNGKey(0))
    srv = Server(params, CFG, n_slots=1, max_len=64, dtype=jnp.float32)
    for r in range(3):
        srv.submit(Request(rid=r, prompt=np.arange(4, dtype=np.int32) + r,
                           max_new=3))
    done = srv.run()
    assert sorted(d.rid for d in done) == [0, 1, 2]


def test_swa_ring_buffer_decode():
    """With window W, decoding past W positions must equal the full forward
    (which masks beyond the window) - the rolling cache is lossless."""
    cfg = ModelConfig(name="swa", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv=2, d_head=8, d_ff=64, vocab=64,
                      swa_window=6, dtype="float32")
    params, _ = init_lm(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    S, extra = 5, 8                       # decode well past the window
    toks = jnp.asarray(rng.integers(0, 64, (1, S + extra)).astype(np.int32))
    from repro.models.transformer import backbone, embed_tokens
    from repro.models.layers import rms_norm
    h = embed_tokens(params, cfg, toks, jnp.float32)
    x = backbone(params, cfg, h, jnp.arange(S + extra), dtype=jnp.float32,
                 remat=False)
    ref = rms_norm(x, params["final_norm"], cfg.norm_eps) @ \
        params["embed"].astype(jnp.float32).T
    cache = init_cache(cfg, 1, 64, jnp.float32)
    assert cache["k"].shape[2] == 6       # ring buffer = window
    lg, cache = prefill(params, cfg, {"tokens": toks[:, :S]}, cache,
                        dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ref[:, S - 1]),
                               atol=1e-4)
    for t in range(extra):
        lg, cache = decode_step(params, cfg, toks[:, S + t], cache,
                                dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(ref[:, S + t]), atol=1e-4)


@pytest.mark.parametrize("family", ["ssm", "hybrid", "moe"])
def test_server_other_families(family):
    cfgs = {
        "ssm": ModelConfig(name="s", family="ssm", n_layers=2, d_model=32,
                           n_heads=0, n_kv=0, d_head=0, d_ff=0, vocab=64,
                           ssm_state=8, ssm_head_dim=8, ssm_chunk=8,
                           dtype="float32"),
        "hybrid": ModelConfig(name="h", family="hybrid", n_layers=2,
                              d_model=32, n_heads=4, n_kv=4, d_head=8,
                              d_ff=64, vocab=64, ssm_state=8,
                              ssm_head_dim=8, ssm_chunk=8, attn_every=2,
                              dtype="float32"),
        "moe": ModelConfig(name="m", family="moe", n_layers=2, d_model=32,
                           n_heads=4, n_kv=2, d_head=8, d_ff=0, vocab=64,
                           moe_experts=4, moe_top_k=2, moe_d_ff=48,
                           moe_capacity=8.0, dtype="float32"),
    }
    cfg = cfgs[family]
    params, _ = init_lm(cfg, jax.random.PRNGKey(2))
    srv = Server(params, cfg, n_slots=2, max_len=32, dtype=jnp.float32)
    prompts = [np.arange(4, dtype=np.int32) + r for r in range(2)]
    for r, pr in enumerate(prompts):
        srv.submit(Request(rid=r, prompt=pr, max_new=4))
    done = srv.run()
    assert len(done) == 2
    for d in done:
        assert d.out == _single(params, cfg, prompts[d.rid], 4, max_len=32)
