import os

# Tests run on the single host device.  The 512-device environment is ONLY
# for launch/dryrun.py (set there before any jax import); distributed tests
# spawn subprocesses with their own XLA_FLAGS.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
