import os
import tempfile

# src/ reaches sys.path via pyproject [tool.pytest.ini_options] pythonpath
# (inserted before this conftest is imported; pytest>=7 is pinned).

# Tests run on the single host device.  The 512-device environment is ONLY
# for launch/dryrun.py (set there before any jax import); distributed tests
# spawn subprocesses with their own XLA_FLAGS.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Keep layouts hermetic: a developer's local autotune sweep (written to
# results/tuning/) must not leak tuned tile geometry into default
# build_layout() calls under test.  Tests that exercise the tuning cache
# set REPRO_TUNING_DIR / cache_dir themselves.
os.environ["REPRO_TUNING_DIR"] = tempfile.mkdtemp(
    prefix="repro-tuning-test-")

# Install the JAX version shims (jax.sharding.AxisType, new-style
# AbstractMesh, make_mesh(axis_types=...)) before test modules import them.
import repro.dist.compat  # noqa: E402,F401

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
