"""Launcher integration: the production train/serve entrypoints run
end-to-end in --smoke mode, including checkpoint-resume across invocations
(the restart path of fault tolerance)."""
import os
import subprocess
import sys
import tempfile

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


def _run(args, timeout=600):
    r = subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                       text=True, env=ENV, timeout=timeout, cwd=REPO)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_train_launcher_smoke_and_resume():
    ckpt = tempfile.mkdtemp()
    out = _run(["repro.launch.train", "--arch", "qwen2-0.5b", "--smoke",
                "--steps", "6", "--ckpt-every", "3", "--ckpt", ckpt])
    assert "[train] done" in out
    # resume: a second invocation picks up from the checkpoint
    out = _run(["repro.launch.train", "--arch", "qwen2-0.5b", "--smoke",
                "--steps", "8", "--ckpt-every", "4", "--ckpt", ckpt])
    assert "resumed at step 6" in out


@pytest.mark.slow
def test_serve_launcher_smoke():
    out = _run(["repro.launch.serve", "--arch", "mamba2-780m", "--smoke",
                "--requests", "3", "--slots", "2", "--max-new", "4"])
    assert "3 requests" in out


@pytest.mark.slow
def test_dryrun_single_cell_small():
    """dryrun lowers+compiles on the production mesh from a clean process
    (uses the cached cell if present; --force would recompile)."""
    out = _run(["repro.launch.dryrun", "--arch", "qwen2-0.5b",
                "--shape", "decode_32k", "--mesh", "single"],
               timeout=1200)
    assert "[ok]" in out or "[skip-cached]" in out
