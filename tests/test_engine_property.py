"""Property tests (hypothesis): the PPM engine's system invariants.

Main property: for ANY graph and ANY mode (hybrid / SC / DC / Pallas), one
PPM iteration equals the vertex-centric push oracle — i.e. the paper's
correctness contract "same result as sequential, without locks/atomics".
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.apps import bfs, connected_components, sssp
from repro.core import monoid as M
from repro.graph import build_layout, from_edges, to_scipy
import scipy.sparse.csgraph as csg


def _random_graph(data, weighted=False):
    n = data.draw(st.integers(2, 48))
    m = data.draw(st.integers(1, 256))
    seed = data.draw(st.integers(0, 10**6))
    rng = np.random.default_rng(seed)
    w = rng.random(m).astype(np.float32) + 0.05 if weighted else None
    g = from_edges(rng.integers(0, n, m), rng.integers(0, n, m), n=n,
                   weights=w, dedup=True)
    k = data.draw(st.sampled_from([1, 2, 4]))
    L = build_layout(g, k=min(k, n), edge_tile=8, msg_tile=8)
    return g, L


@settings(max_examples=20, deadline=None)
@given(st.data())
def test_bfs_matches_oracle_any_graph(data):
    g, L = _random_graph(data)
    src = data.draw(st.integers(0, g.n - 1))
    mode = data.draw(st.sampled_from(["hybrid", "sc", "dc"]))
    res = bfs(L, source=src, mode=mode)
    d = csg.shortest_path(to_scipy(g), method="D", unweighted=True,
                          indices=src)
    ref = np.where(np.isinf(d), -1, d).astype(int)
    assert np.array_equal(res["level"], ref)


@settings(max_examples=15, deadline=None)
@given(st.data())
def test_sssp_matches_oracle_any_graph(data):
    g, L = _random_graph(data, weighted=True)
    src = data.draw(st.integers(0, g.n - 1))
    mode = data.draw(st.sampled_from(["hybrid", "sc", "dc"]))
    res = sssp(L, source=src, mode=mode)
    ref = csg.shortest_path(to_scipy(g), method="D", indices=src)
    fin = ~np.isinf(ref)
    assert np.array_equal(np.isinf(res["dist"]), ~fin)
    np.testing.assert_allclose(res["dist"][fin], ref[fin], atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_cc_partition_refinement(data):
    g, L = _random_graph(data)
    # symmetrize
    src = np.repeat(np.arange(g.n), g.out_degrees())
    gs = from_edges(np.concatenate([src, g.indices]),
                    np.concatenate([g.indices, src]), n=g.n, dedup=True)
    Ls = build_layout(gs, k=min(4, g.n), edge_tile=8, msg_tile=8)
    ours = connected_components(Ls)["label"]
    ncc, ref = csg.connected_components(to_scipy(gs), directed=False)
    for comp in range(ncc):
        assert len(np.unique(ours[ref == comp])) == 1
    assert len(np.unique(ours)) == ncc


# ---------------------------------------------------------------------------
# monoid laws (the gather fold must be a commutative monoid - DESIGN.md §2)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mk", [lambda: M.add(jnp.float32),
                                lambda: M.min_(jnp.uint32),
                                lambda: M.max_(jnp.float32),
                                lambda: M.or_()])
@settings(max_examples=20, deadline=None)
@given(a=st.integers(0, 2**31 - 1), b=st.integers(0, 2**31 - 1),
       c=st.integers(0, 2**31 - 1))
def test_monoid_laws(mk, a, b, c):
    m = mk()
    xs = [jnp.asarray(v, m.dtype) if not jnp.issubdtype(m.dtype, jnp.floating)
          else jnp.asarray(v / 2**16, m.dtype) for v in (a, b, c)]
    x, y, z = xs
    i = jnp.asarray(m.identity, m.dtype)
    assert m.combine(x, i) == x                       # identity
    assert m.combine(x, y) == m.combine(y, x)         # commutativity
    lhs = m.combine(m.combine(x, y), z)
    rhs = m.combine(x, m.combine(y, z))
    if jnp.issubdtype(m.dtype, jnp.floating) and m.name == "add":
        np.testing.assert_allclose(float(lhs), float(rhs), rtol=1e-5)
    else:
        assert lhs == rhs                             # associativity


def test_min_with_payload_packing():
    import jax
    with jax.experimental.enable_x64():       # uint64 lattice needs x64
        key = jnp.asarray([0.5, 0.25, 3.0], jnp.float32)
        pay = jnp.asarray([7, 9, 11], jnp.uint32)
        packed = M.pack_key_payload(key, pay)
        best = packed.min()
        k, p = M.unpack_key_payload(best)
        assert float(k) == 0.25 and int(p) == 9
