"""Property tests (hypothesis): the fused scatter→fold DC step.

Registry kernel ``fused_dc`` (:mod:`repro.kernels.fused_step`) replaces
the composed scatter → slot gather → segmented fold of the DC stream
with one Pallas launch.  Its contract must be BIT-exact against both the
pure-jnp oracle (``ref_fused_scatter_fold``, what the ``ref`` backend
registers) and the hand-composed gather→fold through the existing fold
kernels, for ANY graph-shaped input: duplicate source slots, empty
frontiers (all table slots invalid), all-invalid edge tiles, over-cap
segment spaces (``ns > REPRO_FOLD_MAX_SEGMENTS``), non-power-of-two
``fold_q``, and edge streams that do not divide the edge tile.

Strategies, monoid×dtype combos ({add,min,max}×{f32,i32,u32}), and the
comparator come from the shared differential harness
(``tests/kernel_harness.py``); payloads are integer-valued so even the
f32 add fold is exact and every comparison is bit-for-bit.

Engine-level parity (``REPRO_FUSED=1`` vs ``0``) and the 2-device
shard_map leg mirror ``test_apps_overcap.py``: exact for the
order-independent CC min-monoid.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from kernel_harness import (NS_Q_PAIRS, NUM_SEGMENTS, assert_kernel_equiv,
                            draw_fused_case, draw_monoid, payload,
                            segment_oracle)
from repro.backend import registry
from repro.kernels.fold_two_level import two_level_segment_fold
from repro.kernels.fused_step import (ENV_FUSED, fused_scatter_fold,
                                      ref_fused_scatter_fold)

EDGE_TILES = (8, 16)
FOLD_QS = (3, 7, 8)       # non-pow2 bucket widths are first-class


def _relax(v, w):
    """sssp-style edge function for the apply_weight leg; module-level so
    the jit cache keys on ONE callable across hypothesis examples."""
    return v + w


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_fused_matches_ref_oracle(data):
    monoid, dtype, mono = draw_monoid(data)
    ns = data.draw(st.sampled_from(NUM_SEGMENTS))
    tile = data.draw(st.sampled_from(EDGE_TILES))
    q = data.draw(st.sampled_from(FOLD_QS))
    table, tvalid, idx, evalid, dst = draw_fused_case(data, ns, dtype)
    assert_kernel_equiv(
        lambda *a: fused_scatter_fold(*a, ns, monoid=monoid,
                                      edge_tile=tile, fold_q=q,
                                      interpret=True),
        lambda *a: ref_fused_scatter_fold(mono, *a, ns),
        (table, tvalid, idx, evalid, dst))


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_fused_matches_composed_gather_fold_overcap(data):
    """fused ≡ the composed lowering it replaces (explicit table gather,
    then the two-level fold kernel), across the over-cap NS_Q_PAIRS —
    the regime where both sides run the bucketed grid."""
    monoid, dtype, mono = draw_monoid(data)
    ns, q = data.draw(st.sampled_from(NS_Q_PAIRS))
    tile = data.draw(st.sampled_from(EDGE_TILES))
    table, tvalid, idx, evalid, dst = draw_fused_case(data, ns, dtype)

    def composed(table, tvalid, idx, evalid, dst):
        vals = table[idx].astype(mono.dtype)
        valid = tvalid[idx] & evalid
        vals = jnp.where(valid, vals, mono.identity)
        # invalid edges route out of range; the fold contract drops them
        ids = jnp.where(valid, dst, ns)
        return two_level_segment_fold(vals, valid, ids, ns, monoid=monoid,
                                      fold_tile=tile, fold_q=q,
                                      interpret=True)

    assert_kernel_equiv(
        lambda *a: fused_scatter_fold(*a, ns, monoid=monoid,
                                      edge_tile=tile, fold_q=q,
                                      interpret=True),
        composed,
        (table, tvalid, idx, evalid, dst))


@settings(max_examples=20, deadline=None)
@given(st.data())
def test_fused_registry_backends_agree(data):
    """The registry triple: the ``pallas-interpret`` stream kernel and the
    ``ref`` stream kernel implement the same ``fused_dc`` contract,
    apply_weight included (the sssp-style relax keeps integer payloads
    integer, so the check stays bit-exact)."""
    monoid, dtype, mono = draw_monoid(data)
    ns = data.draw(st.sampled_from(NUM_SEGMENTS))
    q = data.draw(st.sampled_from(FOLD_QS))
    table, tvalid, idx, evalid, dst = draw_fused_case(data, ns, dtype)
    rng = np.random.default_rng(data.draw(st.integers(0, 10**6)))
    w = payload(rng, idx.shape[0], dtype)
    if np.dtype(dtype).kind != "u":
        w = jnp.abs(w)                        # keep uint semantics aligned

    pk = registry.BACKENDS["pallas-interpret"].fused_stream(mono, tile=8,
                                                            q=q)
    rk = registry.BACKENDS["ref"].fused_stream(mono)
    args = (table, tvalid, idx, evalid, dst, ns, w, _relax)
    assert_kernel_equiv(pk, rk, args)


def test_fused_empty_and_all_invalid():
    """Deterministic extremes: zero edges, an empty frontier (no valid
    table slot), and an all-invalid edge stream all return pure identity
    with nothing touched."""
    from repro.core import monoid as M
    mono = M.min_(jnp.uint32)
    ns = 11
    table = jnp.arange(7, dtype=jnp.uint32)
    cases = [
        (table, jnp.ones(7, bool), jnp.zeros(0, jnp.int32),
         jnp.zeros(0, bool), jnp.zeros(0, jnp.int32)),          # no edges
        (table, jnp.zeros(7, bool), jnp.zeros(9, jnp.int32),
         jnp.ones(9, bool), jnp.zeros(9, jnp.int32)),     # empty frontier
        (table, jnp.ones(7, bool), jnp.zeros(9, jnp.int32),
         jnp.zeros(9, bool), jnp.zeros(9, jnp.int32)),    # all-pad edges
    ]
    for args in cases:
        acc, touched = fused_scatter_fold(*args, ns, monoid="min",
                                          edge_tile=8, fold_q=4,
                                          interpret=True)
        assert np.array_equal(np.asarray(acc),
                              np.full(ns, mono.identity, np.uint32))
        assert not np.asarray(touched).any()


def test_fused_out_of_range_dst_contributes_nothing():
    """dst outside [0, num_segments) — negative or past the padding —
    lands nowhere, matching the fold contract the engines rely on for
    the overflow bin."""
    ns = 10
    table = jnp.ones((4,), jnp.float32)
    tv = jnp.ones((4,), bool)
    idx = jnp.zeros((8,), jnp.int32)
    ev = jnp.ones((8,), bool)
    dst = jnp.asarray(np.array([0, 5, 9, 10, 11, 50, -3, -1], np.int32))
    acc, touched = fused_scatter_fold(table, tv, idx, ev, dst, ns,
                                      monoid="add", edge_tile=4, fold_q=3,
                                      interpret=True)
    want = np.zeros(ns, np.float32)
    want[[0, 5, 9]] = 1.0
    assert np.array_equal(np.asarray(acc), want)
    assert np.array_equal(np.asarray(touched), want > 0)


# ----------------------------------------------------------------------
# engine-level parity: REPRO_FUSED=1 vs =0 must be invisible to results
# ----------------------------------------------------------------------


def _cc_labels(layout, mode):
    from repro.apps.cc import connected_components
    return connected_components(layout, mode=mode)["label"]


def test_engine_fused_parity_cc(monkeypatch):
    """Core engine: the fused DC lowering and the composed path produce
    bit-identical CC labels (min/uint32 is order-independent), in pure-DC
    and hybrid modes.  REPRO_FUSED is read at Engine construction, so
    flipping the env between runs flips the lowering."""
    from repro.graph import build_layout, rmat
    g = rmat(7, 8, seed=3)
    L = build_layout(g, k=4, edge_tile=32, msg_tile=16)
    for mode in ("dc", "hybrid"):
        monkeypatch.setenv(ENV_FUSED, "1")
        fused = _cc_labels(L, mode)
        monkeypatch.setenv(ENV_FUSED, "0")
        composed = _cc_labels(L, mode)
        assert np.array_equal(fused, composed)


def test_engine_fused_parity_add_monoid(monkeypatch):
    """Add-monoid parity through run_fused (PageRank's fixed-iteration DC
    loop): integer-valued f32 payloads keep the sum exact under either
    reduction order, so the comparison is bit-for-bit."""
    import jax
    from repro.core.engine import Engine
    from repro.core.program import VertexProgram
    from repro.core import monoid as M
    from repro.graph import build_layout, rmat

    def scatter_fn(state):
        return state["x"]

    def apply_fn(state, acc, touched, it):
        x = jnp.where(touched, state["x"] + acc, state["x"])
        return dict(state, x=x), touched

    prog = VertexProgram(name="sumprop", monoid=M.add(jnp.float32),
                         scatter_fn=scatter_fn, apply_fn=apply_fn)
    g = rmat(6, 8, seed=2)
    L = build_layout(g, k=4, edge_tile=32, msg_tile=16)
    rng = np.random.default_rng(0)
    x0 = jnp.asarray(rng.integers(0, 8, L.n_pad).astype(np.float32))
    frontier = np.zeros(L.n_pad, bool)
    frontier[:L.n] = True

    outs = {}
    for flag in ("1", "0"):
        monkeypatch.setenv(ENV_FUSED, flag)
        eng = Engine(L, prog, mode="dc")
        assert (eng._fused is not None) == (flag == "1")
        state, _ = eng.run_fused({"x": x0}, frontier, iters=2)
        outs[flag] = np.asarray(state["x"])
    assert np.array_equal(outs["1"], outs["0"])


@pytest.mark.slow
def test_dist_cc_fused_parity_shard_map(monkeypatch):
    """The fused kernel must trace inside shard_map: CC through DistEngine
    on 2 virtual devices with the fold cap lowered (over-cap two-level
    regime), REPRO_FUSED=1 vs =0 bit parity."""
    import os
    import subprocess
    import sys
    import textwrap
    code = """
    import os
    import numpy as np
    import jax.numpy as jnp
    from repro.dist.compat import AxisType, make_mesh
    from repro.graph import rmat, build_layout
    from repro.graph.shard import shard_layout
    from repro.dist.engine import DistEngine
    from repro.apps.cc import cc_program
    D = 2
    mesh = make_mesh((D,), ("dev",), axis_types=(AxisType.Auto,))
    g = rmat(8, 8, seed=5)
    L = build_layout(g, k=4, edge_tile=64, msg_tile=32)
    SL = shard_layout(L, D)
    assert SL.nv + 1 > 16          # cap lowered to 16 via env below
    N = D * SL.nv
    outs = {}
    for flag in ("1", "0"):
        os.environ["REPRO_FUSED"] = flag
        eng = DistEngine(SL, cc_program(), mesh, mode="dc")
        assert (eng.fused_backend_name is not None) == (flag == "1")
        label = jnp.arange(N, dtype=jnp.uint32)
        frontier = np.zeros(N, bool); frontier[:g.n] = True
        state, _, _ = eng.run({"label": label}, frontier)
        outs[flag] = np.asarray(state["label"])[:g.n]
    assert np.array_equal(outs["1"], outs["0"])
    print("dist fused parity ok")
    """
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               REPRO_FOLD_MAX_SEGMENTS="16",
               PYTHONPATH=os.path.join(repo, "src"))
    env.pop("REPRO_KERNEL_BACKEND", None)
    env.pop("REPRO_FUSED", None)
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "dist fused parity ok" in r.stdout
