"""Per-assigned-architecture smoke tests: a REDUCED config of each family
runs one train step (and one decode step where applicable) on CPU, asserting
output shapes and no NaNs (brief requirement f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, all_cells, get_config, \
    get_smoke_config
from repro.models.transformer import init_lm, lm_loss
from repro.serve.engine import decode_step, init_cache, prefill


@pytest.mark.parametrize("arch", list(ARCHS))
def test_smoke_train_step(arch, rng):
    cfg = get_smoke_config(arch)
    params, axes = init_lm(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = {"labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))}
    if cfg.frontend is not None:
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)).astype(np.float32))
    else:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))

    def loss_fn(p):
        return lm_loss(p, cfg, batch, dtype=jnp.float32)

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    assert 0 < float(loss) < 3 * np.log(cfg.vocab)
    for leaf in jax.tree_util.tree_leaves(grads):
        assert bool(jnp.isfinite(leaf).all()), f"{arch}: NaN grads"


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_config(a).decoder])
def test_smoke_decode_step(arch, rng):
    cfg = get_smoke_config(arch)
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    B, S = 2, 8
    cache = init_cache(cfg, B, 32, jnp.float32)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)).astype(np.int32))
    if cfg.frontend is not None:
        batch = {"embeds": jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)).astype(np.float32))}
    else:
        batch = {"tokens": toks}
    logits, cache = prefill(params, cfg, batch, cache, dtype=jnp.float32)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    logits, cache = decode_step(params, cfg, jnp.zeros((B,), jnp.int32),
                                cache, dtype=jnp.float32)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert int(cache["len"][0]) == S + 1


def test_full_configs_match_brief():
    """The FULL configs carry the exact assigned hyperparameters."""
    expect = {
        "zamba2-7b": dict(n_layers=81, d_model=3584, n_heads=32,
                          d_ff=14336, vocab=32000, ssm_state=64),
        "mamba2-780m": dict(n_layers=48, d_model=1536, vocab=50280,
                            ssm_state=128),
        "yi-34b": dict(n_layers=60, d_model=7168, n_heads=56, n_kv=8,
                       d_ff=20480, vocab=64000),
        "mistral-nemo-12b": dict(n_layers=40, d_model=5120, n_heads=32,
                                 n_kv=8, d_ff=14336, vocab=131072),
        "qwen2-0.5b": dict(n_layers=24, d_model=896, n_heads=14, n_kv=2,
                           d_ff=4864, vocab=151936, qkv_bias=True),
        "yi-6b": dict(n_layers=32, d_model=4096, n_heads=32, n_kv=4,
                      d_ff=11008, vocab=64000),
        "llama4-scout-17b-a16e": dict(n_layers=48, d_model=5120, n_heads=40,
                                      n_kv=8, vocab=202048, moe_experts=16,
                                      moe_top_k=1, moe_d_ff=8192),
        "mixtral-8x7b": dict(n_layers=32, d_model=4096, n_heads=32, n_kv=8,
                             vocab=32000, moe_experts=8, moe_top_k=2,
                             moe_d_ff=14336, swa_window=4096),
        "pixtral-12b": dict(n_layers=40, d_model=5120, n_heads=32, n_kv=8,
                            d_ff=14336, vocab=131072, frontend="patch"),
        "hubert-xlarge": dict(n_layers=48, d_model=1280, n_heads=16,
                              n_kv=16, d_ff=5120, vocab=504, causal=False),
    }
    for arch, fields in expect.items():
        cfg = get_config(arch)
        for f, v in fields.items():
            assert getattr(cfg, f) == v, f"{arch}.{f}"


def test_cell_matrix_covers_40():
    cells = list(all_cells())
    assert len(cells) == 40
    runs = [c for c in cells if c[2] == "run"]
    skips = [c for c in cells if c[2] != "run"]
    assert len(runs) == 32 and len(skips) == 8
    # every skip carries a documented reason
    for _, _, reason in skips:
        assert reason.startswith("skip:")
