"""Partition-level semantic cache: backend protocol conformance,
landmark-seeded warm starts (exactly equal to cold runs, fewer-or-equal
iterations), invalidation on clear/swap, and the async warmer.

The seeding correctness contract under test (see repro/serve/cache.py):
on a symmetric graph, initializing a min-monoid program from
``d_L(v) + d_L(s)`` upper bounds (landmark L, source s) converges to the
bit-exact cold-start fixpoint — int monoids bit-exact, f32 within 1e-6.
"""
import dataclasses
import warnings

import numpy as np
import pytest

from repro.apps import (bfs, bfs_multi, bfs_seeded_multi, sssp, sssp_multi)
from repro.graph import build_layout, grid2d, rmat, symmetrize
from repro.serve import (CacheBackend, DiskCache, GraphQuery,
                         GraphQueryServer, MemoryLRU, ServeConfig,
                         make_backend)
from repro.serve import cache as cache_lib


# ----------------------------------------------------------------------
# fixtures
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def sym_layout():
    """Symmetric structure AND weights: the full seeding precondition."""
    g = symmetrize(rmat(8, 8, seed=3, weighted=True))
    return build_layout(g, k=8, edge_tile=64, msg_tile=32)


@pytest.fixture(scope="module")
def grid_layout():
    """Large-diameter symmetric graph (seeding saves many iterations)."""
    g = symmetrize(grid2d(16, 16, weighted=True, seed=0))
    return build_layout(g, k=8, edge_tile=64, msg_tile=32)


@pytest.fixture(scope="module")
def asym_layout():
    """Directed rmat: seeding must be auto-disabled."""
    g = rmat(8, 8, seed=3, weighted=True)
    return build_layout(g, k=8, edge_tile=64, msg_tile=32)


def backends(tmp_path):
    return [MemoryLRU(capacity=4),
            DiskCache(str(tmp_path / "disk"), capacity=4)]


# ----------------------------------------------------------------------
# CacheBackend protocol
# ----------------------------------------------------------------------

class TestBackendProtocol:
    def test_protocol_conformance(self, tmp_path):
        for b in backends(tmp_path):
            assert isinstance(b, CacheBackend)

    def test_roundtrip_arrays_bitexact_and_nested_meta(self, tmp_path):
        arr = np.array([1.5, np.inf, -0.0], np.float32)
        u64 = np.arange(4, dtype=np.uint64) << np.uint64(60)
        for b in backends(tmp_path):
            b.put("k", {"a": arr, "u": u64,
                        "meta": {"iters": 3, "fills": {"dist": None}}})
            v = b.get("k")
            assert np.array_equal(v["a"], arr) and v["a"].dtype == arr.dtype
            assert np.array_equal(v["u"], u64) and v["u"].dtype == u64.dtype
            assert v["meta"]["iters"] == 3
            assert v["meta"]["fills"]["dist"] is None

    def test_lru_eviction_under_capacity(self, tmp_path):
        for b in backends(tmp_path):
            for i in range(6):
                b.put(f"k{i}", {"i": np.asarray([i])})
            assert len(b) == 4
            assert b.keys() == ["k2", "k3", "k4", "k5"]
            # get() refreshes recency: k2 survives the next eviction
            assert b.get("k2") is not None
            b.put("k6", {"i": np.asarray([6])})
            assert "k2" in b.keys() and "k3" not in b.keys()
            st = b.stats()
            assert st["entries"] == 4 and st["evictions"] == 3
            assert st["puts"] == 7

    def test_evict_and_clear(self, tmp_path):
        for b in backends(tmp_path):
            b.put("x", {"v": np.zeros(2)})
            assert b.evict("x") and not b.evict("x")
            assert b.get("x") is None
            b.put("y", {"v": np.zeros(2)})
            b.clear()
            assert len(b) == 0 and b.keys() == []

    def test_disk_cache_survives_reopen(self, tmp_path):
        path = str(tmp_path / "persist")
        b = DiskCache(path, capacity=8)
        b.put("keep", {"a": np.arange(3.0)})
        b.put("drop", {"a": np.arange(2.0)})
        b.evict("drop")
        b2 = DiskCache(path, capacity=8)          # replays index.jsonl
        assert b2.keys() == ["keep"]
        assert np.array_equal(b2.get("keep")["a"], np.arange(3.0))
        b2.clear()
        assert len(DiskCache(path, capacity=8)) == 0

    def test_make_backend_specs(self, tmp_path):
        assert isinstance(make_backend(None, 8), MemoryLRU)
        d = make_backend(str(tmp_path / "d"), 8)
        assert isinstance(d, DiskCache)
        inst = MemoryLRU(2)
        assert make_backend(inst, 99) is inst


# ----------------------------------------------------------------------
# key space
# ----------------------------------------------------------------------

class TestKeySpace:
    def test_keys_are_canonical_and_namespaced(self):
        k1 = cache_lib.result_key("L", "bfs", {"source": 3, "max_iters": 9})
        k2 = cache_lib.result_key("L", "bfs", {"max_iters": 9, "source": 3})
        assert k1 == k2 and k1.startswith("res|L|bfs|")
        s = cache_lib.semantic_key("L", "sssp", {}, 7)
        assert s.startswith("sem|L|sssp|") and s.endswith("|src=7")
        assert s.startswith(cache_lib.semantic_prefix("L", "sssp", {}))
        # res and sem never collide (distinct namespaces)
        assert not s.startswith("res|")

    def test_uncanonicalizable_params_yield_none(self):
        assert cache_lib.canon_params({"seeds": {0: 1}}) is None
        assert cache_lib.result_key("L", "bfs", {"x": {0: 1}}) is None
        assert cache_lib.semantic_key("L", "bfs", {"x": {0: 1}}, 0) is None

    def test_layout_tag_is_content_derived(self, sym_layout, asym_layout):
        assert cache_lib.layout_tag(sym_layout) == \
            cache_lib.layout_tag(sym_layout)
        assert cache_lib.layout_tag(sym_layout) != \
            cache_lib.layout_tag(asym_layout)


# ----------------------------------------------------------------------
# symmetry detection + weighted symmetrize
# ----------------------------------------------------------------------

class TestSymmetry:
    def test_weighted_symmetrize_canonicalizes_weights(self):
        g = grid2d(8, 8, weighted=True, seed=0)
        lay = build_layout(g, k=4, edge_tile=64, msg_tile=32)
        # grid weights are drawn independently per direction
        assert cache_lib.layout_is_symmetric(lay, weights=False)
        assert not cache_lib.layout_is_symmetric(lay, weights=True)
        gs = symmetrize(g)
        lays = build_layout(gs, k=4, edge_tile=64, msg_tile=32)
        assert cache_lib.layout_is_symmetric(lays, weights=True)

    def test_symmetrize_takes_min_weight_per_pair(self):
        from repro.graph import from_edges
        g = from_edges([0, 1], [1, 0], n=2,
                       weights=np.asarray([3.0, 1.0], np.float32))
        gs = symmetrize(g)
        assert gs.m == 2
        assert np.allclose(gs.weights, [1.0, 1.0])

    def test_directed_graph_detected(self, asym_layout):
        assert not cache_lib.layout_is_symmetric(asym_layout, weights=False)


# ----------------------------------------------------------------------
# landmark-seeded warm start == cold start
# ----------------------------------------------------------------------

def _seed_sssp_from_landmark(layout, semantic, landmark_res, lm, src):
    n_pad = layout.n_pad
    full = np.full(n_pad, np.inf, np.float32)
    full[:layout.n] = landmark_res["dist"]
    semantic.put_state("sssp", {}, lm, {"dist": full},
                       np.isfinite(full), {"dist": np.inf},
                       iters=len(landmark_res["stats"]))
    pick = semantic.best_landmark("sssp", {}, src, "dist")
    assert pick is not None and pick[0] == lm
    seed = semantic.expand(pick[1], "dist", np.inf) + np.float32(pick[2])
    seed[src] = 0.0
    return seed


class TestSeededEqualsCold:
    @pytest.mark.parametrize("fixture", ["sym_layout", "grid_layout"])
    def test_sssp_seeded_matches_cold_within_1e6(self, fixture, request):
        lay = request.getfixturevalue(fixture)
        sem = cache_lib.SemanticCache(MemoryLRU(8), "t", lay.k, lay.q,
                                      lay.n_pad)
        lm, src = 0, min(17, lay.n - 1)
        cold_lm = sssp_multi(lay, [lm])
        seed = _seed_sssp_from_landmark(
            lay, sem, {"dist": cold_lm["dist"][0],
                       "stats": cold_lm["stats"]}, lm, src)
        warm = sssp_multi(lay, [src], dist0=seed[None],
                          frontier0=np.isfinite(seed)[None])
        cold = sssp_multi(lay, [src])
        w, c = warm["dist"][0], cold["dist"][0]
        assert np.array_equal(np.isinf(w), np.isinf(c))
        fin = np.isfinite(c)
        assert np.abs(w[fin] - c[fin]).max() <= 1e-6
        assert len(warm["stats"]) <= len(cold["stats"])

    def test_seeded_bfs_cold_run_bitexact_with_stock(self, sym_layout):
        sources = [0, 7, 99]
        stock = bfs_multi(sym_layout, sources)
        seeded = bfs_seeded_multi(sym_layout, sources)
        assert np.array_equal(stock["level"], seeded["level"])
        assert np.array_equal(stock["parent"], seeded["parent"])
        assert len(stock["stats"]) == len(seeded["stats"])

    @pytest.mark.parametrize("fixture", ["sym_layout", "grid_layout"])
    def test_bfs_seeded_matches_cold_bitexact(self, fixture, request):
        lay = request.getfixturevalue(fixture)
        lm, src = 0, min(17, lay.n - 1)
        cold_lm = bfs_multi(lay, [lm])
        n_pad = lay.n_pad
        B = 1
        levels = np.full((B, n_pad), -1, np.int64)
        lv = np.full(n_pad, -1, np.int64)
        lv[:lay.n] = cold_lm["level"][0]
        d_ls = int(lv[src])
        if d_ls < 0:
            pytest.skip("source unreachable from landmark in this graph")
        lv[lv >= 0] += d_ls
        lv[src] = 0
        levels[0] = lv
        parents = np.full((B, n_pad), -1, np.int64)
        parents[0, src] = src
        warm = bfs_seeded_multi(lay, [src], seed_levels=levels,
                                seed_parents=parents,
                                frontiers=(levels >= 0))
        cold = bfs_multi(lay, [src])
        assert np.array_equal(warm["level"], cold["level"])
        assert np.array_equal(warm["parent"], cold["parent"])
        assert len(warm["stats"]) <= len(cold["stats"])

    def test_self_landmark_seeding_converges_immediately(self, grid_layout):
        """An exact seed (the landmark itself) converges in one sweep —
        the strongest iteration-savings case."""
        lay = grid_layout
        cold = sssp_multi(lay, [0])
        sem = cache_lib.SemanticCache(MemoryLRU(8), "t", lay.k, lay.q,
                                      lay.n_pad)
        seed = _seed_sssp_from_landmark(lay, sem, {"dist": cold["dist"][0],
                                                   "stats": cold["stats"]},
                                        0, 0)
        warm = sssp_multi(lay, [0], dist0=seed[None],
                          frontier0=np.isfinite(seed)[None])
        assert np.array_equal(warm["dist"][0], cold["dist"][0])
        assert len(warm["stats"]) < len(cold["stats"])


def test_seeded_equivalence_property():
    """Hypothesis property: on random symmetrized graphs, landmark-seeded
    BFS/SSSP equals cold start for every (landmark, source) pair drawn."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16), lm=st.integers(0, 63),
           src=st.integers(0, 63))
    def prop(seed, lm, src):
        g = symmetrize(rmat(6, 6, seed=seed, weighted=True))
        lay = build_layout(g, k=4, edge_tile=64, msg_tile=32)
        sem = cache_lib.SemanticCache(MemoryLRU(4), "t", lay.k, lay.q,
                                      lay.n_pad)
        cold_lm = sssp_multi(lay, [lm])
        if not np.isfinite(cold_lm["dist"][0][src]):
            return                      # disconnected pair: nothing to seed
        sd = _seed_sssp_from_landmark(
            lay, sem, {"dist": cold_lm["dist"][0],
                       "stats": cold_lm["stats"]}, lm, src)
        warm = sssp_multi(lay, [src], dist0=sd[None],
                          frontier0=np.isfinite(sd)[None])
        cold = sssp_multi(lay, [src])
        w, c = warm["dist"][0], cold["dist"][0]
        assert np.array_equal(np.isinf(w), np.isinf(c))
        fin = np.isfinite(c)
        assert np.abs(w[fin] - c[fin]).max() <= 1e-6

    prop()


# ----------------------------------------------------------------------
# server integration
# ----------------------------------------------------------------------

class TestServerSemantics:
    def _drain(self, srv, app, sources, qid0=0):
        for i, s in enumerate(sources):
            srv.submit(GraphQuery(qid=qid0 + i, app=app,
                                  params={"source": int(s)}))
        srv.run()
        return {int(q.params["source"]): q.result for q in srv.done
                if q.app == app}

    @pytest.mark.parametrize("app", ["bfs", "sssp"])
    def test_warm_queries_equal_cold(self, sym_layout, app):
        cold_srv = GraphQueryServer(sym_layout, ServeConfig(semantic=False))
        warm_srv = GraphQueryServer(sym_layout, ServeConfig())
        self._drain(warm_srv, app, [5, 9])          # landmarks captured
        warm = self._drain(warm_srv, app, [40, 77], qid0=10)
        cold = self._drain(cold_srv, app, [40, 77])
        for s in (40, 77):
            if app == "bfs":
                assert np.array_equal(warm[s]["level"], cold[s]["level"])
                assert np.array_equal(warm[s]["parent"], cold[s]["parent"])
            else:
                w, c = warm[s]["dist"], cold[s]["dist"]
                assert np.array_equal(np.isinf(w), np.isinf(c))
                fin = np.isfinite(c)
                assert np.abs(w[fin] - c[fin]).max() <= 1e-6
        assert warm_srv.semantic_hits + warm_srv.semantic_misses > 0
        assert warm_srv.semantic.landmarks(app, {})  # capture happened

    def test_seeding_disabled_on_asymmetric_layout(self, asym_layout):
        srv = GraphQueryServer(asym_layout, ServeConfig())
        self._drain(srv, "sssp", [5, 9])
        self._drain(srv, "sssp", [40], qid0=10)
        # no landmark state, no semantic lookups on a directed graph
        assert srv.semantic.landmarks("sssp", {}) == []
        assert srv.semantic_hits == srv.semantic_misses == 0

    def test_invalidation_on_swap_layout(self, sym_layout, grid_layout):
        srv = GraphQueryServer(sym_layout, ServeConfig())
        self._drain(srv, "sssp", [5, 9])
        assert srv.semantic.landmarks("sssp", {})
        srv.swap_layout(grid_layout)
        assert len(srv.cache) == 0
        assert srv.semantic.landmarks("sssp", {}) == []
        # warm state never crosses layouts: fresh queries run cold+exact
        warm = self._drain(srv, "sssp", [17], qid0=50)
        ref = sssp(grid_layout, 17)
        fin = np.isfinite(ref["dist"])
        assert np.array_equal(np.isinf(warm[17]["dist"]),
                              np.isinf(ref["dist"]))
        assert np.abs(warm[17]["dist"][fin] - ref["dist"][fin]).max() \
            <= 1e-6

    def test_invalidation_on_clear_cache(self, sym_layout):
        srv = GraphQueryServer(sym_layout, ServeConfig())
        self._drain(srv, "bfs", [5, 9])
        assert srv.semantic.landmarks("bfs", {})
        srv.clear_cache()
        assert len(srv.cache) == 0
        assert srv.semantic.landmarks("bfs", {}) == []

    def test_semantic_entries_respect_backend_capacity(self, sym_layout):
        srv = GraphQueryServer(sym_layout,
                               ServeConfig(cache_size=3, max_batch=4))
        self._drain(srv, "bfs", [1, 2, 3, 4])
        # 4 result entries + up to 4 semantic entries through capacity 3
        assert len(srv.cache) <= 3
        assert srv.cache.stats()["evictions"] > 0

    def test_warmer_promotes_hot_sources(self, sym_layout):
        srv = GraphQueryServer(
            sym_layout, ServeConfig(capture_landmarks=False,
                                    warm_threshold=2, warm_budget=4))
        for i in range(3):
            srv.submit(GraphQuery(qid=i, app="sssp",
                                  params={"source": 123}))
            srv.run()                   # idle at end of each run: warms
        assert srv.semantic.landmarks("sssp", {}) == [123]
        # the warmed landmark also memoized the exact result
        key = cache_lib.result_key(srv._layout_tag, "sssp",
                                   {"source": 123})
        assert srv.cache.get(key) is not None

    def test_disk_backed_server_cache(self, sym_layout, tmp_path):
        path = str(tmp_path / "srvcache")
        srv = GraphQueryServer(sym_layout,
                               ServeConfig(cache_backend=path))
        res = self._drain(srv, "sssp", [5])
        # a second server over the SAME layout content reuses the disk
        # entries (content-derived layout tag)
        srv2 = GraphQueryServer(sym_layout,
                                ServeConfig(cache_backend=path))
        self._drain(srv2, "sssp", [5])
        assert srv2.cache_hits == 1 and srv2.cache_misses == 0
        got = srv2.done[0].result
        assert np.allclose(got["dist"], res[5]["dist"], atol=0, rtol=0,
                           equal_nan=True)


class TestServeConfigShim:
    def test_legacy_kwargs_warn_and_apply(self, sym_layout):
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            srv = GraphQueryServer(sym_layout, max_batch=8, cache_size=2)
        assert any(issubclass(w.category, DeprecationWarning) for w in rec)
        assert srv.max_batch == 8 and srv.config.max_batch == 8
        assert srv.cache_size == 2 and srv.config.cache_size == 2

    def test_config_object_does_not_warn(self, sym_layout):
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            srv = GraphQueryServer(sym_layout, ServeConfig(max_batch=8))
        assert not [w for w in rec
                    if issubclass(w.category, DeprecationWarning)]
        assert srv.max_batch == 8

    def test_unknown_legacy_kwarg_raises(self, sym_layout):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            with pytest.raises(TypeError, match="unknown"):
                GraphQueryServer(sym_layout, bogus=1)

    def test_config_is_dataclass_with_documented_fields(self):
        names = {f.name for f in dataclasses.fields(ServeConfig)}
        assert {"backend", "mode", "max_batch", "cache_size",
                "cache_backend", "semantic", "capture_landmarks",
                "seed_max_distance", "warm_threshold",
                "warm_budget"} <= names
