"""Partition-level semantic cache: backend protocol conformance,
landmark-seeded warm starts (exactly equal to cold runs, fewer-or-equal
iterations), invalidation on clear/swap, and the async warmer.

The seeding correctness contract under test (see repro/serve/cache.py):
on a symmetric graph, initializing a min-monoid program from
``d_L(v) + d_L(s)`` upper bounds (landmark L, source s) converges to the
bit-exact cold-start fixpoint — int monoids bit-exact, f32 within 1e-6.
"""
import dataclasses
import warnings

import numpy as np
import pytest

from repro.apps import (bfs, bfs_multi, bfs_seeded_multi, sssp, sssp_multi)
from repro.graph import build_layout, grid2d, rmat, symmetrize
from repro.serve import (CacheBackend, DiskCache, GraphQuery,
                         GraphQueryServer, MemoryLRU, ServeConfig,
                         make_backend)
from repro.serve import cache as cache_lib


# ----------------------------------------------------------------------
# fixtures
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def sym_layout():
    """Symmetric structure AND weights: the full seeding precondition."""
    g = symmetrize(rmat(8, 8, seed=3, weighted=True))
    return build_layout(g, k=8, edge_tile=64, msg_tile=32)


@pytest.fixture(scope="module")
def grid_layout():
    """Large-diameter symmetric graph (seeding saves many iterations)."""
    g = symmetrize(grid2d(16, 16, weighted=True, seed=0))
    return build_layout(g, k=8, edge_tile=64, msg_tile=32)


@pytest.fixture(scope="module")
def asym_layout():
    """Directed rmat: seeding must be auto-disabled."""
    g = rmat(8, 8, seed=3, weighted=True)
    return build_layout(g, k=8, edge_tile=64, msg_tile=32)


def backends(tmp_path):
    return [MemoryLRU(capacity=4),
            DiskCache(str(tmp_path / "disk"), capacity=4)]


# ----------------------------------------------------------------------
# CacheBackend protocol
# ----------------------------------------------------------------------

class TestBackendProtocol:
    def test_protocol_conformance(self, tmp_path):
        for b in backends(tmp_path):
            assert isinstance(b, CacheBackend)

    def test_roundtrip_arrays_bitexact_and_nested_meta(self, tmp_path):
        arr = np.array([1.5, np.inf, -0.0], np.float32)
        u64 = np.arange(4, dtype=np.uint64) << np.uint64(60)
        for b in backends(tmp_path):
            b.put("k", {"a": arr, "u": u64,
                        "meta": {"iters": 3, "fills": {"dist": None}}})
            v = b.get("k")
            assert np.array_equal(v["a"], arr) and v["a"].dtype == arr.dtype
            assert np.array_equal(v["u"], u64) and v["u"].dtype == u64.dtype
            assert v["meta"]["iters"] == 3
            assert v["meta"]["fills"]["dist"] is None

    def test_lru_eviction_under_capacity(self, tmp_path):
        for b in backends(tmp_path):
            for i in range(6):
                b.put(f"k{i}", {"i": np.asarray([i])})
            assert len(b) == 4
            assert b.keys() == ["k2", "k3", "k4", "k5"]
            # get() refreshes recency: k2 survives the next eviction
            assert b.get("k2") is not None
            b.put("k6", {"i": np.asarray([6])})
            assert "k2" in b.keys() and "k3" not in b.keys()
            st = b.stats()
            assert st["entries"] == 4 and st["evictions"] == 3
            assert st["puts"] == 7

    def test_evict_and_clear(self, tmp_path):
        for b in backends(tmp_path):
            b.put("x", {"v": np.zeros(2)})
            assert b.evict("x") and not b.evict("x")
            assert b.get("x") is None
            b.put("y", {"v": np.zeros(2)})
            b.clear()
            assert len(b) == 0 and b.keys() == []

    def test_disk_cache_survives_reopen(self, tmp_path):
        path = str(tmp_path / "persist")
        b = DiskCache(path, capacity=8)
        b.put("keep", {"a": np.arange(3.0)})
        b.put("drop", {"a": np.arange(2.0)})
        b.evict("drop")
        b2 = DiskCache(path, capacity=8)          # replays index.jsonl
        assert b2.keys() == ["keep"]
        assert np.array_equal(b2.get("keep")["a"], np.arange(3.0))
        b2.clear()
        assert len(DiskCache(path, capacity=8)) == 0

    def test_make_backend_specs(self, tmp_path):
        assert isinstance(make_backend(None, 8), MemoryLRU)
        d = make_backend(str(tmp_path / "d"), 8)
        assert isinstance(d, DiskCache)
        inst = MemoryLRU(2)
        assert make_backend(inst, 99) is inst

    def test_evict_prefix_default_and_helper(self, tmp_path):
        for b in backends(tmp_path):
            b.put("res|A|x", {"v": np.zeros(1)})
            b.put("res|A|y", {"v": np.zeros(1)})
            b.put("res|B|x", {"v": np.zeros(1)})
            b.put("sem|A|x", {"v": np.zeros(1)})
            assert cache_lib.evict_prefix(b, "res|A|") == 2
            assert sorted(b.keys()) == ["res|B|x", "sem|A|x"]
            assert cache_lib.evict_prefix(b, "res|A|") == 0


class TestDiskCacheCompaction:
    """A churned DiskCache directory must not grow without bound: the
    append-only index.jsonl is compacted on open once the op count dwarfs
    the live entries, and orphaned .npz payloads are unlinked."""

    def _churn(self, path, rounds=20):
        b = DiskCache(path, capacity=2)
        for i in range(rounds):
            b.put(f"k{i}", {"i": np.asarray([i])})
        return b

    def test_index_compacts_on_open(self, tmp_path):
        import os
        path = str(tmp_path / "churn")
        self._churn(path)                       # 20 puts through cap 2
        idx = os.path.join(path, "index.jsonl")
        with open(idx) as fh:
            assert sum(1 for _ in fh) > 2 * DiskCache.COMPACT_MIN_OPS
        b2 = DiskCache(path, capacity=2)        # compacts on open
        with open(idx) as fh:
            assert sum(1 for _ in fh) == 2
        assert b2.keys() == ["k18", "k19"]
        assert np.array_equal(b2.get("k19")["i"], [19])

    def test_orphaned_payloads_unlinked(self, tmp_path):
        import os
        path = str(tmp_path / "orphans")
        self._churn(path)
        # plant an orphan payload no index record points at
        orphan = os.path.join(path, "deadbeefdeadbeefdead.npz")
        with open(orphan, "wb") as fh:
            fh.write(b"junk")
        b2 = DiskCache(path, capacity=2)
        assert not os.path.exists(orphan)
        # exactly one payload per live entry remains
        npz = [f for f in os.listdir(path) if f.endswith(".npz")]
        assert len(npz) == len(b2) == 2

    def test_small_logs_left_alone_and_reopen_idempotent(self, tmp_path):
        import os
        path = str(tmp_path / "small")
        b = DiskCache(path, capacity=8)
        b.put("a", {"v": np.zeros(1)})
        b.put("b", {"v": np.zeros(1)})
        idx = os.path.join(path, "index.jsonl")
        with open(idx) as fh:
            before = fh.read()
        DiskCache(path, capacity=8)             # 2 ops: below threshold
        with open(idx) as fh:
            assert fh.read() == before
        # compaction is idempotent: a second open after churn is a no-op
        path2 = str(tmp_path / "twice")
        self._churn(path2)
        DiskCache(path2, capacity=2)
        idx2 = os.path.join(path2, "index.jsonl")
        with open(idx2) as fh:
            once = fh.read()
        DiskCache(path2, capacity=2)
        with open(idx2) as fh:
            assert fh.read() == once


# ----------------------------------------------------------------------
# key space
# ----------------------------------------------------------------------

class TestKeySpace:
    def test_keys_are_canonical_and_namespaced(self):
        k1 = cache_lib.result_key("L", "bfs", {"source": 3, "max_iters": 9})
        k2 = cache_lib.result_key("L", "bfs", {"max_iters": 9, "source": 3})
        assert k1 == k2 and k1.startswith("res|L|bfs|")
        s = cache_lib.semantic_key("L", "sssp", {}, 7)
        assert s.startswith("sem|L|sssp|") and s.endswith("|src=7")
        assert s.startswith(cache_lib.semantic_prefix("L", "sssp", {}))
        # res and sem never collide (distinct namespaces)
        assert not s.startswith("res|")

    def test_uncanonicalizable_params_yield_none(self):
        assert cache_lib.canon_params({"seeds": {0: 1}}) is None
        assert cache_lib.result_key("L", "bfs", {"x": {0: 1}}) is None
        assert cache_lib.semantic_key("L", "bfs", {"x": {0: 1}}, 0) is None

    def test_layout_tag_is_content_derived(self, sym_layout, asym_layout):
        assert cache_lib.layout_tag(sym_layout) == \
            cache_lib.layout_tag(sym_layout)
        assert cache_lib.layout_tag(sym_layout) != \
            cache_lib.layout_tag(asym_layout)


# ----------------------------------------------------------------------
# symmetry detection + weighted symmetrize
# ----------------------------------------------------------------------

class TestSymmetry:
    def test_weighted_symmetrize_canonicalizes_weights(self):
        g = grid2d(8, 8, weighted=True, seed=0)
        lay = build_layout(g, k=4, edge_tile=64, msg_tile=32)
        # grid weights are drawn independently per direction
        assert cache_lib.layout_is_symmetric(lay, weights=False)
        assert not cache_lib.layout_is_symmetric(lay, weights=True)
        gs = symmetrize(g)
        lays = build_layout(gs, k=4, edge_tile=64, msg_tile=32)
        assert cache_lib.layout_is_symmetric(lays, weights=True)

    def test_symmetrize_takes_min_weight_per_pair(self):
        from repro.graph import from_edges
        g = from_edges([0, 1], [1, 0], n=2,
                       weights=np.asarray([3.0, 1.0], np.float32))
        gs = symmetrize(g)
        assert gs.m == 2
        assert np.allclose(gs.weights, [1.0, 1.0])

    def test_directed_graph_detected(self, asym_layout):
        assert not cache_lib.layout_is_symmetric(asym_layout, weights=False)


# ----------------------------------------------------------------------
# landmark-seeded warm start == cold start
# ----------------------------------------------------------------------

def _seed_sssp_from_landmark(layout, semantic, landmark_res, lm, src):
    n_pad = layout.n_pad
    full = np.full(n_pad, np.inf, np.float32)
    full[:layout.n] = landmark_res["dist"]
    semantic.put_state("sssp", {}, lm, {"dist": full},
                       np.isfinite(full), {"dist": np.inf},
                       iters=len(landmark_res["stats"]))
    pick = semantic.best_landmark("sssp", {}, src, "dist")
    assert pick is not None and pick[0] == lm
    seed = semantic.expand(pick[1], "dist", np.inf) + np.float32(pick[2])
    seed[src] = 0.0
    return seed


class TestSeededEqualsCold:
    @pytest.mark.parametrize("fixture", ["sym_layout", "grid_layout"])
    def test_sssp_seeded_matches_cold_within_1e6(self, fixture, request):
        lay = request.getfixturevalue(fixture)
        sem = cache_lib.SemanticCache(MemoryLRU(8), "t", lay.k, lay.q,
                                      lay.n_pad)
        lm, src = 0, min(17, lay.n - 1)
        cold_lm = sssp_multi(lay, [lm])
        seed = _seed_sssp_from_landmark(
            lay, sem, {"dist": cold_lm["dist"][0],
                       "stats": cold_lm["stats"]}, lm, src)
        warm = sssp_multi(lay, [src], dist0=seed[None],
                          frontier0=np.isfinite(seed)[None])
        cold = sssp_multi(lay, [src])
        w, c = warm["dist"][0], cold["dist"][0]
        assert np.array_equal(np.isinf(w), np.isinf(c))
        fin = np.isfinite(c)
        assert np.abs(w[fin] - c[fin]).max() <= 1e-6
        assert len(warm["stats"]) <= len(cold["stats"])

    def test_seeded_bfs_cold_run_bitexact_with_stock(self, sym_layout):
        sources = [0, 7, 99]
        stock = bfs_multi(sym_layout, sources)
        seeded = bfs_seeded_multi(sym_layout, sources)
        assert np.array_equal(stock["level"], seeded["level"])
        assert np.array_equal(stock["parent"], seeded["parent"])
        assert len(stock["stats"]) == len(seeded["stats"])

    @pytest.mark.parametrize("fixture", ["sym_layout", "grid_layout"])
    def test_bfs_seeded_matches_cold_bitexact(self, fixture, request):
        lay = request.getfixturevalue(fixture)
        lm, src = 0, min(17, lay.n - 1)
        cold_lm = bfs_multi(lay, [lm])
        n_pad = lay.n_pad
        B = 1
        levels = np.full((B, n_pad), -1, np.int64)
        lv = np.full(n_pad, -1, np.int64)
        lv[:lay.n] = cold_lm["level"][0]
        d_ls = int(lv[src])
        if d_ls < 0:
            pytest.skip("source unreachable from landmark in this graph")
        lv[lv >= 0] += d_ls
        lv[src] = 0
        levels[0] = lv
        parents = np.full((B, n_pad), -1, np.int64)
        parents[0, src] = src
        warm = bfs_seeded_multi(lay, [src], seed_levels=levels,
                                seed_parents=parents,
                                frontiers=(levels >= 0))
        cold = bfs_multi(lay, [src])
        assert np.array_equal(warm["level"], cold["level"])
        assert np.array_equal(warm["parent"], cold["parent"])
        assert len(warm["stats"]) <= len(cold["stats"])

    def test_self_landmark_seeding_converges_immediately(self, grid_layout):
        """An exact seed (the landmark itself) converges in one sweep —
        the strongest iteration-savings case."""
        lay = grid_layout
        cold = sssp_multi(lay, [0])
        sem = cache_lib.SemanticCache(MemoryLRU(8), "t", lay.k, lay.q,
                                      lay.n_pad)
        seed = _seed_sssp_from_landmark(lay, sem, {"dist": cold["dist"][0],
                                                   "stats": cold["stats"]},
                                        0, 0)
        warm = sssp_multi(lay, [0], dist0=seed[None],
                          frontier0=np.isfinite(seed)[None])
        assert np.array_equal(warm["dist"][0], cold["dist"][0])
        assert len(warm["stats"]) < len(cold["stats"])


def test_seeded_equivalence_property():
    """Hypothesis property: on random symmetrized graphs, landmark-seeded
    BFS/SSSP equals cold start for every (landmark, source) pair drawn."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16), lm=st.integers(0, 63),
           src=st.integers(0, 63))
    def prop(seed, lm, src):
        g = symmetrize(rmat(6, 6, seed=seed, weighted=True))
        lay = build_layout(g, k=4, edge_tile=64, msg_tile=32)
        sem = cache_lib.SemanticCache(MemoryLRU(4), "t", lay.k, lay.q,
                                      lay.n_pad)
        cold_lm = sssp_multi(lay, [lm])
        if not np.isfinite(cold_lm["dist"][0][src]):
            return                      # disconnected pair: nothing to seed
        sd = _seed_sssp_from_landmark(
            lay, sem, {"dist": cold_lm["dist"][0],
                       "stats": cold_lm["stats"]}, lm, src)
        warm = sssp_multi(lay, [src], dist0=sd[None],
                          frontier0=np.isfinite(sd)[None])
        cold = sssp_multi(lay, [src])
        w, c = warm["dist"][0], cold["dist"][0]
        assert np.array_equal(np.isinf(w), np.isinf(c))
        fin = np.isfinite(c)
        assert np.abs(w[fin] - c[fin]).max() <= 1e-6

    prop()


# ----------------------------------------------------------------------
# server integration
# ----------------------------------------------------------------------

class TestServerSemantics:
    def _drain(self, srv, app, sources, qid0=0):
        for i, s in enumerate(sources):
            srv.submit(GraphQuery(qid=qid0 + i, app=app,
                                  params={"source": int(s)}))
        srv.run()
        return {int(q.params["source"]): q.result for q in srv.done
                if q.app == app}

    @pytest.mark.parametrize("app", ["bfs", "sssp"])
    def test_warm_queries_equal_cold(self, sym_layout, app):
        cold_srv = GraphQueryServer(sym_layout, ServeConfig(semantic=False))
        warm_srv = GraphQueryServer(sym_layout, ServeConfig())
        self._drain(warm_srv, app, [5, 9])          # landmarks captured
        warm = self._drain(warm_srv, app, [40, 77], qid0=10)
        cold = self._drain(cold_srv, app, [40, 77])
        for s in (40, 77):
            if app == "bfs":
                assert np.array_equal(warm[s]["level"], cold[s]["level"])
                assert np.array_equal(warm[s]["parent"], cold[s]["parent"])
            else:
                w, c = warm[s]["dist"], cold[s]["dist"]
                assert np.array_equal(np.isinf(w), np.isinf(c))
                fin = np.isfinite(c)
                assert np.abs(w[fin] - c[fin]).max() <= 1e-6
        assert warm_srv.semantic_hits + warm_srv.semantic_misses > 0
        assert warm_srv.semantic.landmarks(app, {})  # capture happened

    def test_seeding_disabled_on_asymmetric_layout(self, asym_layout):
        srv = GraphQueryServer(asym_layout, ServeConfig())
        self._drain(srv, "sssp", [5, 9])
        self._drain(srv, "sssp", [40], qid0=10)
        # no landmark state, no semantic lookups on a directed graph
        assert srv.semantic.landmarks("sssp", {}) == []
        assert srv.semantic_hits == srv.semantic_misses == 0

    def test_plain_swap_is_scoped_not_wholesale(self, sym_layout,
                                                grid_layout):
        """A plain ``swap_layout`` evicts NOTHING: entries are keyed by
        content tag, so the old layout's entries become invisible under
        the new tag rather than being destroyed."""
        srv = GraphQueryServer(sym_layout, ServeConfig())
        self._drain(srv, "sssp", [5, 9])
        assert srv.semantic.landmarks("sssp", {})
        n_before = len(srv.cache)
        assert n_before > 0
        srv.swap_layout(grid_layout)
        assert srv.epoch == 1
        assert len(srv.cache) == n_before          # nothing evicted
        # ...but warm state never crosses layouts: the new tag's
        # namespace is empty and fresh queries run cold+exact
        assert srv.semantic.landmarks("sssp", {}) == []
        warm = self._drain(srv, "sssp", [17], qid0=50)
        ref = sssp(grid_layout, 17)
        fin = np.isfinite(ref["dist"])
        assert np.array_equal(np.isinf(warm[17]["dist"]),
                              np.isinf(ref["dist"]))
        assert np.abs(warm[17]["dist"][fin] - ref["dist"][fin]).max() \
            <= 1e-6

    def test_swap_back_retains_disk_entries(self, sym_layout, grid_layout,
                                            tmp_path):
        """Regression for the wholesale-clear bug: swap A -> B -> A on a
        DiskCache must retain A's entries and serve a semantic hit after
        the swap back (PR 8 keys entries by content tag precisely so
        they survive this)."""
        cfg = ServeConfig(cache_backend=str(tmp_path / "abab"),
                          cache_size=64)
        srv = GraphQueryServer(sym_layout, cfg)
        self._drain(srv, "sssp", [5, 9])
        tag_a = srv._layout_tag
        a_keys = {k for k in srv.cache.keys() if f"|{tag_a}|" in k}
        assert a_keys and srv.semantic.landmarks("sssp", {})
        srv.swap_layout(grid_layout)                # A -> B
        srv.swap_layout(sym_layout)                 # B -> A
        assert srv.epoch == 2 and srv._layout_tag == tag_a
        assert a_keys <= set(srv.cache.keys())      # survived both swaps
        # landmark state is live again under A's tag...
        assert srv.semantic.landmarks("sssp", {})
        # ...and actually serves: exact-result hit on a repeat query and
        # a semantic (landmark-seeded) path for a brand-new source
        h0 = srv.cache_hits
        self._drain(srv, "sssp", [5], qid0=80)
        assert srv.cache_hits == h0 + 1
        self._drain(srv, "sssp", [77], qid0=90)   # reachable from lm 5
        assert srv.semantic_hits > 0

    def test_invalidation_on_clear_cache(self, sym_layout):
        srv = GraphQueryServer(sym_layout, ServeConfig())
        self._drain(srv, "bfs", [5, 9])
        assert srv.semantic.landmarks("bfs", {})
        srv.clear_cache()
        assert len(srv.cache) == 0
        assert srv.semantic.landmarks("bfs", {}) == []

    def test_semantic_entries_respect_backend_capacity(self, sym_layout):
        srv = GraphQueryServer(sym_layout,
                               ServeConfig(cache_size=3, max_batch=4))
        self._drain(srv, "bfs", [1, 2, 3, 4])
        # 4 result entries + up to 4 semantic entries through capacity 3
        assert len(srv.cache) <= 3
        assert srv.cache.stats()["evictions"] > 0

    def test_warmer_promotes_hot_sources(self, sym_layout):
        srv = GraphQueryServer(
            sym_layout, ServeConfig(capture_landmarks=False,
                                    warm_threshold=2, warm_budget=4))
        for i in range(3):
            srv.submit(GraphQuery(qid=i, app="sssp",
                                  params={"source": 123}))
            srv.run()                   # idle at end of each run: warms
        assert srv.semantic.landmarks("sssp", {}) == [123]
        # the warmed landmark also memoized the exact result
        key = cache_lib.result_key(srv._layout_tag, "sssp",
                                   {"source": 123})
        assert srv.cache.get(key) is not None

    def test_warmer_not_starved_under_sustained_load(self, sym_layout):
        """The warmer gets its budget every ``step()``, not only when the
        queue drains: with a saturated queue (one query per step, queue
        never empty) a hot source must still be promoted within a few
        steps of crossing the threshold."""
        srv = GraphQueryServer(
            sym_layout, ServeConfig(capture_landmarks=False, max_batch=1,
                                    warm_threshold=2, warm_budget=4))
        hot = 123
        # two hits on `hot` first, then enough filler to keep the queue
        # non-empty for many steps
        sources = [hot, hot] + [10 + i for i in range(8)]
        for i, s in enumerate(sources):
            srv.submit(GraphQuery(qid=i, app="sssp",
                                  params={"source": int(s)}))
        steps = 0
        while srv.semantic.landmarks("sssp", {}) != [hot]:
            assert srv.queue, "queue drained before the warmer fired"
            assert srv.step() > 0
            steps += 1
            assert steps <= 4, "warmer starved under sustained load"
        assert srv.queue                 # load is still pending: no idle
        srv.run()                        # drain the rest; results stay ok
        assert hot in {int(q.params["source"]) for q in srv.done}

    def test_disk_backed_server_cache(self, sym_layout, tmp_path):
        path = str(tmp_path / "srvcache")
        srv = GraphQueryServer(sym_layout,
                               ServeConfig(cache_backend=path))
        res = self._drain(srv, "sssp", [5])
        # a second server over the SAME layout content reuses the disk
        # entries (content-derived layout tag)
        srv2 = GraphQueryServer(sym_layout,
                                ServeConfig(cache_backend=path))
        self._drain(srv2, "sssp", [5])
        assert srv2.cache_hits == 1 and srv2.cache_misses == 0
        got = srv2.done[0].result
        assert np.allclose(got["dist"], res[5]["dist"], atol=0, rtol=0,
                           equal_nan=True)


class TestServeConfigShim:
    def test_legacy_kwargs_warn_and_apply(self, sym_layout):
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            srv = GraphQueryServer(sym_layout, max_batch=8, cache_size=2)
        assert any(issubclass(w.category, DeprecationWarning) for w in rec)
        assert srv.max_batch == 8 and srv.config.max_batch == 8
        assert srv.cache_size == 2 and srv.config.cache_size == 2

    def test_config_object_does_not_warn(self, sym_layout):
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            srv = GraphQueryServer(sym_layout, ServeConfig(max_batch=8))
        assert not [w for w in rec
                    if issubclass(w.category, DeprecationWarning)]
        assert srv.max_batch == 8

    def test_unknown_legacy_kwarg_raises(self, sym_layout):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            with pytest.raises(TypeError, match="unknown"):
                GraphQueryServer(sym_layout, bogus=1)

    def test_config_is_dataclass_with_documented_fields(self):
        names = {f.name for f in dataclasses.fields(ServeConfig)}
        assert {"backend", "mode", "max_batch", "cache_size",
                "cache_backend", "semantic", "capture_landmarks",
                "seed_max_distance", "warm_threshold",
                "warm_budget"} <= names
