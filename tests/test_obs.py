"""Tests for the repro.obs telemetry layer.

Covers the metrics registry (histogram percentile math against
numpy.percentile, label-subset resets), the exporters (JSONL round-trip,
Prometheus text format, the checked-in schema JSON staying in sync with
``EVENT_SCHEMA``), the engine and serve-tier wiring (events validate,
cost samples accumulate, server counters match the obs series, layout
swaps segment the hit-rate series), and the disabled-mode no-op
guarantee (no events, no metrics, no extra jit retraces).
"""
import importlib.util
import json
import math
from pathlib import Path

import numpy as np
import pytest

from repro import obs
from repro.obs import schema as obs_schema
from repro.obs.export import (JsonlSink, prometheus_text, read_jsonl,
                              write_jsonl)
from repro.obs.metrics import Histogram, Registry

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    _HYP = True
except ImportError:                                  # pragma: no cover
    _HYP = False

REPO_ROOT = Path(__file__).resolve().parents[1]
G = Histogram.GROWTH


@pytest.fixture(scope="module")
def layout():
    from repro.graph import build_layout, rmat
    g = rmat(8, 8, seed=3)
    return build_layout(g, k=4, edge_tile=64, msg_tile=32)


@pytest.fixture()
def obs_on():
    """Telemetry forced ON with a clean default registry, restored after."""
    with obs.override_enabled(True):
        obs.reset()
        yield obs.registry()
    obs.reset()


def _load_tool(name):
    spec = importlib.util.spec_from_file_location(
        name, REPO_ROOT / "tools" / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ----------------------------------------------------------------------
# histogram percentile math
# ----------------------------------------------------------------------

def _check_bracket(samples, p):
    """The log-bucketed estimate must land within one bucket's relative
    width of numpy's linear-interpolated percentile (G per order
    statistic; G**2 total slack absorbs bucket-boundary rounding)."""
    h = Histogram("t", {})
    for v in samples:
        h.observe(v)
    est = h.percentile(p)
    ref = float(np.percentile(np.asarray(samples, float), p))
    assert h.min <= est <= h.max
    assert ref / G**2 - 1e-12 <= est <= ref * G**2 + 1e-12


class TestHistogram:
    def test_empty_is_nan(self):
        assert math.isnan(Histogram("t", {}).percentile(50))

    def test_single_value_exact(self):
        h = Histogram("t", {})
        h.observe(0.125)
        for p in (0, 50, 100):
            assert h.percentile(p) == 0.125

    def test_counts_sum_min_max(self):
        h = Histogram("t", {})
        for v in (1.0, 2.0, 3.0):
            h.observe(v)
        assert (h.n, h.sum, h.min, h.max) == (3, 6.0, 1.0, 3.0)
        s = h.summary()
        assert s["count"] == 3 and s["p50"] == pytest.approx(2.0, rel=G)

    def test_percentiles_bracket_numpy_fixed(self):
        rng = np.random.default_rng(11)
        samples = np.exp(rng.uniform(np.log(1e-6), np.log(1e3), size=500))
        for p in (0, 1, 25, 50, 75, 90, 95, 99, 100):
            _check_bracket(samples, p)

    def test_reset(self):
        h = Histogram("t", {})
        h.observe(1.0)
        h.reset()
        assert h.n == 0 and math.isnan(h.percentile(50))


if _HYP:
    @settings(max_examples=100, deadline=None)
    @given(st.lists(st.floats(min_value=1e-6, max_value=1e6,
                              allow_nan=False, allow_infinity=False),
                    min_size=2, max_size=100),
           st.floats(min_value=0, max_value=100))
    def test_percentile_brackets_numpy_property(samples, p):
        _check_bracket(samples, p)
else:                                                # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_percentile_brackets_numpy_property():
        pass


# ----------------------------------------------------------------------
# registry
# ----------------------------------------------------------------------

class TestRegistry:
    def test_label_series_are_distinct(self):
        r = Registry(enabled=True)
        r.inc("hits", layout="a")
        r.inc("hits", 2, layout="b")
        assert r.counter("hits", layout="a").value == 1
        assert r.counter("hits", layout="b").value == 2
        snap = r.snapshot()
        assert snap["counters"]["hits{layout=a}"] == 1
        assert snap["counters"]["hits{layout=b}"] == 2

    def test_reset_metric_label_subset(self):
        r = Registry(enabled=True)
        r.inc("hits", 3, layout="a", app="bfs")
        r.inc("hits", 5, layout="a", app="sssp")
        r.inc("hits", 7, layout="b", app="bfs")
        r.reset_metric("hits", layout="a")
        assert r.counter("hits", layout="a", app="bfs").value == 0
        assert r.counter("hits", layout="a", app="sssp").value == 0
        assert r.counter("hits", layout="b", app="bfs").value == 7

    def test_cost_sample_filter(self):
        r = Registry(enabled=True)
        r.cost_sample("dc", 100, 0.5, it=0)
        r.cost_sample("sc", 10, 0.1)
        assert r.cost_samples() == [("dc", 100, 0.5), ("sc", 10, 0.1)]
        assert r.cost_samples(mode="sc") == [("sc", 10, 0.1)]

    def test_disabled_records_nothing(self):
        r = Registry(enabled=False)
        r.inc("hits")
        r.set_gauge("depth", 4)
        r.observe("lat", 0.1)
        r.event("engine_iter", engine="core")
        r.cost_sample("dc", 1, 0.1)
        assert r.metrics() == {}
        assert r.events() == []
        assert r.cost_samples() == []


# ----------------------------------------------------------------------
# exporters
# ----------------------------------------------------------------------

class TestExport:
    def test_jsonl_round_trip(self, tmp_path):
        r = Registry(enabled=True)
        r.event("cache_clear", layout="0x1")
        r.event("bench_row", kernel="gather", backend="ref", wall_s=0.25)
        p = tmp_path / "events.jsonl"
        assert write_jsonl(p, r) == 2
        back = read_jsonl(p)
        assert back == r.events()

    def test_streaming_sink(self, tmp_path):
        p = tmp_path / "stream.jsonl"
        r = Registry(enabled=True, sink=str(p))
        r.event("cache_clear", layout="0x1")
        r.close()
        assert len(read_jsonl(p)) == 1
        with JsonlSink(p) as sink:
            sink.emit({"event": "cache_clear", "ts": 0.0, "layout": "x"})
        assert len(read_jsonl(p)) == 2

    def test_prometheus_text_format(self):
        r = Registry(enabled=True)
        r.inc("serve.cache_hits", 3, app="bfs", layout="L1")
        r.set_gauge("serve.queue_depth", 4, layout="L1")
        for v in (0.5, 0.5, 2.0):
            r.observe("lat", v)
        text = prometheus_text(r)
        assert text.endswith("\n")
        assert "# TYPE repro_serve_cache_hits counter" in text
        assert 'repro_serve_cache_hits{app="bfs",layout="L1"} 3' in text
        assert "# TYPE repro_serve_queue_depth gauge" in text
        assert 'repro_serve_queue_depth{layout="L1"} 4' in text
        assert "# TYPE repro_lat histogram" in text
        assert 'repro_lat_bucket{le="+Inf"} 3' in text
        assert "repro_lat_sum 3" in text
        assert "repro_lat_count 3" in text
        # two finite buckets (0.5 x2, 2.0 x1) + the +Inf bound
        assert text.count("repro_lat_bucket{") == 3


# ----------------------------------------------------------------------
# schema + checked-in serialization + stdlib validator
# ----------------------------------------------------------------------

class TestSchema:
    def test_validate_event_accepts_valid(self):
        rec = {"event": "engine_iter", "ts": 1.0, "engine": "core",
               "program": "bfs", "it": 0, "mode": "dc", "n_active": 1,
               "e_active": 8, "wall_s": 0.01, "extra": "ok"}
        assert obs_schema.validate_event(rec) == []

    def test_validate_event_flags_violations(self):
        assert obs_schema.validate_event({"ts": 1.0}) \
            == ["missing/invalid 'event' field"]
        assert obs_schema.validate_event({"event": "nope", "ts": 1.0})
        missing = obs_schema.validate_event(
            {"event": "cache_clear", "ts": 1.0})
        assert any("layout" in m for m in missing)
        # bool is an int subclass: must be rejected where int is asked
        rec = {"event": "engine_iter", "ts": 1.0, "engine": "core",
               "program": "bfs", "it": True, "mode": "dc", "n_active": 1,
               "e_active": 8, "wall_s": 0.01}
        assert any("got bool" in m for m in obs_schema.validate_event(rec))

    def test_schema_json_in_sync(self):
        on_disk = json.loads(
            (REPO_ROOT / "tools" / "obs_schema.json").read_text())
        assert on_disk == obs_schema.EVENT_SCHEMA

    def test_check_obs_schema_cli(self, tmp_path):
        checker = _load_tool("check_obs_schema")
        good = tmp_path / "good.jsonl"
        good.write_text(json.dumps(
            {"event": "cache_clear", "ts": 1.0, "layout": "x"}) + "\n")
        assert checker.main([str(good)]) == 0
        assert checker.main([str(good), "--require", "cache_clear"]) == 0
        assert checker.main([str(good), "--require", "engine_iter"]) == 1
        bad = tmp_path / "bad.jsonl"
        bad.write_text(json.dumps({"event": "cache_clear", "ts": 1.0})
                       + "\nnot json\n")
        assert checker.main([str(bad)]) == 1


# ----------------------------------------------------------------------
# engine wiring
# ----------------------------------------------------------------------

def _bfs_inputs(layout, source=0):
    import jax.numpy as jnp
    n_pad = layout.n_pad
    parent = jnp.full((n_pad,), -1, jnp.int32).at[source].set(source)
    level = jnp.full((n_pad,), -1, jnp.int32).at[source].set(0)
    vid = jnp.arange(n_pad, dtype=jnp.uint32)
    frontier = np.zeros(n_pad, bool)
    frontier[source] = True
    return {"parent": parent, "level": level, "vid": vid}, frontier


class TestEngineTelemetry:
    def test_run_records_events_and_cost_samples(self, obs_on, layout):
        from repro.apps import bfs
        res = bfs(layout, source=0)
        iters = obs.events("engine_iter")
        assert len(iters) == len(res["stats"]) > 0
        for e in iters:
            assert obs_schema.validate_event(e) == []
            assert e["engine"] == "core" and e["program"] == "bfs"
            assert e["mode"] in ("dc", "sc", "hybrid")
        samples = obs.cost_samples()
        assert len(samples) == len(iters)
        mode, size, wall = samples[0]
        assert isinstance(size, int) and wall >= 0

    def test_batched_run_records_batch_iters(self, obs_on, layout):
        from repro.apps.bfs import bfs_multi
        bfs_multi(layout, [0, 1, 2])
        batched = obs.events("batch_iter")
        assert batched
        for e in batched:
            assert obs_schema.validate_event(e) == []
            # the compiled width starts at the submitted B and only
            # shrinks (pow2 compaction) as lanes converge
            assert e["lanes_active"] <= e["width"] <= 3

    def test_collect_stats_false_is_silent(self, obs_on, layout):
        from repro.apps.bfs import bfs_program
        from repro.core.engine import Engine
        eng = Engine(layout, bfs_program(), mode="dc")
        state, frontier = _bfs_inputs(layout)
        eng.run(state, frontier, collect_stats=False)
        assert obs.events("engine_iter") == []
        assert obs.cost_samples() == []

    def test_disabled_mode_no_events_no_retrace(self, layout):
        from repro.apps.bfs import bfs_program
        from repro.core.engine import Engine
        eng = Engine(layout, bfs_program(), mode="dc")
        state, frontier = _bfs_inputs(layout)
        with obs.override_enabled(True):
            obs.reset()
            eng.run(state, frontier)
            n_events = len(obs.events())
            assert n_events > 0
            keys = set(eng._step_cache)
            sizes = {k: fn._cache_size()
                     for k, fn in eng._step_cache.items()
                     if hasattr(fn, "_cache_size")}
            with obs.override_enabled(False):
                eng.run(state, frontier)
                assert len(obs.events()) == n_events
                assert obs.registry().enabled is False
            # same shapes, telemetry toggled: no new jitted steps and no
            # retrace of the existing ones
            assert set(eng._step_cache) == keys
            for k, n in sizes.items():
                assert eng._step_cache[k]._cache_size() == n
            obs.reset()

    def test_iterstats_compat_shim(self):
        import warnings
        from repro.core import engine as core_engine
        # the old names still resolve, but each access warns
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            assert core_engine.IterStats is obs_schema.IterStats
            assert core_engine.BatchIterStats is obs_schema.BatchIterStats
        assert len(rec) == 2
        assert all(issubclass(w.category, DeprecationWarning) for w in rec)
        assert "repro.obs.schema" in str(rec[0].message)
        # the public repro.core re-export stays silent
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            import repro.core
            assert repro.core.IterStats is obs_schema.IterStats
        assert not rec
        # pre-obs positional construction still works
        st_ = obs_schema.IterStats(0, 1, 2, 3, 4, 5.0, 6.0, 0.1)
        assert (st_.mode, st_.program) == ("", "")
        assert obs_schema.as_event(st_)["dc_bytes"] == 5.0


# ----------------------------------------------------------------------
# serve-tier wiring
# ----------------------------------------------------------------------

class TestServeTelemetry:
    def _server(self, layout):
        from repro.serve.engine import GraphQuery, GraphQueryServer
        return GraphQueryServer(layout), GraphQuery

    def test_counters_match_server_ints(self, obs_on, layout):
        srv, GraphQuery = self._server(layout)
        reg = obs.registry()
        for i, s in enumerate([0, 1, 2]):
            srv.submit(GraphQuery(qid=i, app="bfs", params={"source": s}))
        srv.run()
        srv.submit(GraphQuery(qid=9, app="bfs", params={"source": 0}))
        srv.run()
        tag = srv._layout_tag
        hits = reg.counter("serve.cache_hits", layout=tag, app="bfs")
        misses = reg.counter("serve.cache_misses", layout=tag, app="bfs")
        assert srv.cache_hits == hits.value == 1
        assert srv.cache_misses == misses.value == 3
        assert reg.gauge("serve.queue_depth", layout=tag).value == 0
        for e in obs.events("serve_batch") + obs.events("serve_query"):
            assert obs_schema.validate_event(e) == []
        assert any(e["cached"] for e in obs.events("serve_query"))

    def test_clear_cache_resets_layout_series(self, obs_on, layout):
        srv, GraphQuery = self._server(layout)
        reg = obs.registry()
        srv.submit(GraphQuery(qid=0, app="bfs", params={"source": 0}))
        srv.run()
        # a foreign layout's series must survive this server's reset
        reg.inc("serve.cache_misses", 7, layout="other", app="bfs")
        tag = srv._layout_tag
        srv.clear_cache()
        assert srv.cache_hits == srv.cache_misses == 0
        assert reg.counter("serve.cache_misses", layout=tag,
                           app="bfs").value == 0
        assert reg.counter("serve.cache_misses", layout="other",
                           app="bfs").value == 7
        assert obs.events("cache_clear")
        # the result cache is gone: the same query is a miss again
        srv.submit(GraphQuery(qid=1, app="bfs", params={"source": 0}))
        srv.run()
        assert (srv.cache_hits, srv.cache_misses) == (0, 1)

    def test_swap_layout_segments_series(self, obs_on, layout):
        from repro.graph import build_layout, rmat
        srv, GraphQuery = self._server(layout)
        reg = obs.registry()
        srv.submit(GraphQuery(qid=0, app="bfs", params={"source": 0}))
        srv.run()
        old_tag = srv._layout_tag
        g2 = rmat(7, 8, seed=5)
        layout2 = build_layout(g2, k=4, edge_tile=64, msg_tile=32)
        srv.swap_layout(layout2)
        assert srv.layout is layout2
        assert srv._layout_tag != old_tag
        swaps = obs.events("layout_swap")
        assert swaps and obs_schema.validate_event(swaps[-1]) == []
        assert swaps[-1]["old"] == old_tag
        assert swaps[-1]["new"] == srv._layout_tag
        # old layout's series were reset; a plain swap evicts nothing,
        # but the old entry is invisible under the NEW tag, so the
        # repeated query is a miss under the new tag only
        assert reg.counter("serve.cache_misses", layout=old_tag,
                           app="bfs").value == 0
        srv.submit(GraphQuery(qid=1, app="bfs", params={"source": 0}))
        srv.run()
        assert reg.counter("serve.cache_misses", layout=srv._layout_tag,
                           app="bfs").value == 1
        assert (srv.cache_hits, srv.cache_misses) == (0, 1)


# ----------------------------------------------------------------------
# report rendering
# ----------------------------------------------------------------------

def test_obs_report_renders_iteration_table(obs_on, layout):
    from repro.apps import bfs
    bfs(layout, source=0)
    report = _load_tool("obs_report")
    out = report.render(obs.events())
    assert "engine=core program=bfs" in out
    header = next(l for l in out.splitlines() if "mode" in l)
    for col in ("it", "mode", "n_active", "e_active", "wire_B", "wall_ms"):
        assert col in header
