"""End-to-end apps in the over-cap fold regime.

Until this module existed, no test ran any *application* with
``num_segments > REPRO_FOLD_MAX_SEGMENTS`` — the regime where the
registry fold used to hand off to ref silently, and where it now runs the
two-level blocked Pallas fold (:mod:`repro.kernels.fold_two_level`).
Coverage comes from both directions:

  * the cap lowered via the env knob on small graphs (fast — every
    engine fold call crosses into the two-level path), and
  * a genuinely over-cap graph (``nv + 1 > 4096`` at the default cap).

Parity is against the ``ref`` backend selected exactly the way a user
would (``REPRO_KERNEL_BACKEND=ref``): bit-exact for CC (min over uint32
is order-independent), tight allclose for PageRank (f32 sums reassociate
between the blocked and the ``jax.ops`` fold).

The SC engine mode is used because it is the single-device path that
feeds the registry fold every iteration (the DC stream folds through the
layout-bound gather kernel instead).
"""
import numpy as np
import pytest

from repro.apps import connected_components, pagerank
from repro.backend import registry
from repro.graph import build_layout, from_edges, rmat
from repro.kernels.fold_block import (DEFAULT_FOLD_MAX_SEGMENTS,
                                      ENV_FOLD_MAX_SEGMENTS,
                                      max_fold_segments)


@pytest.fixture(scope="module")
def small_layout():
    g = rmat(8, 8, seed=5)
    return build_layout(g, k=8, edge_tile=64, msg_tile=32)


def _overcap_graph():
    """n just past the default cap, low diameter (CC converges fast):
    a hub star plus a sprinkling of chords."""
    n = DEFAULT_FOLD_MAX_SEGMENTS + 128
    rng = np.random.default_rng(7)
    src = np.concatenate([np.zeros(n - 1, np.int64),
                          rng.integers(0, n, 2 * n)])
    dst = np.concatenate([np.arange(1, n, dtype=np.int64),
                          rng.integers(0, n, 2 * n)])
    return from_edges(src, dst, n=n, dedup=True)


def test_pagerank_sc_overcap_via_env(small_layout, monkeypatch):
    """Lowered cap: every SC-stream fold call runs two-level; results
    track the env-selected ref backend to f32 reassociation tolerance."""
    monkeypatch.setenv(registry.ENV_VAR, "ref")
    want = pagerank(small_layout, iters=4, mode="sc", fused=False)["pr"]
    monkeypatch.delenv(registry.ENV_VAR)
    monkeypatch.setenv(ENV_FOLD_MAX_SEGMENTS, "16")
    assert small_layout.n_pad + 1 > max_fold_segments()
    got = pagerank(small_layout, iters=4, mode="sc", fused=False,
                   backend="pallas-interpret")["pr"]
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-9)


def test_cc_sc_overcap_via_env(small_layout, monkeypatch):
    """Lowered cap, CC: min/uint32 folds are order-independent, so the
    two-level path must be BIT-identical to the ref backend."""
    monkeypatch.setenv(registry.ENV_VAR, "ref")
    want = connected_components(small_layout, mode="sc")["label"]
    monkeypatch.delenv(registry.ENV_VAR)
    monkeypatch.setenv(ENV_FOLD_MAX_SEGMENTS, "16")
    got = connected_components(small_layout, mode="sc",
                               backend="pallas-interpret")["label"]
    assert np.array_equal(got, want)


def test_pagerank_cc_true_overcap(monkeypatch):
    """nv + 1 > 4096 at the DEFAULT cap: the handoff regime the paper's
    scalability story lives in, end to end through Engine mode='sc'."""
    monkeypatch.delenv(ENV_FOLD_MAX_SEGMENTS, raising=False)
    g = _overcap_graph()
    L = build_layout(g, k=8)
    assert L.n_pad + 1 > DEFAULT_FOLD_MAX_SEGMENTS

    monkeypatch.setenv(registry.ENV_VAR, "ref")
    pr_want = pagerank(L, iters=2, mode="sc", fused=False)["pr"]
    cc_want = connected_components(L, mode="sc")["label"]
    monkeypatch.delenv(registry.ENV_VAR)

    pr_got = pagerank(L, iters=2, mode="sc", fused=False,
                      backend="pallas-interpret")["pr"]
    np.testing.assert_allclose(pr_got, pr_want, rtol=1e-6, atol=1e-9)
    cc_got = connected_components(L, mode="sc",
                                  backend="pallas-interpret")["label"]
    assert np.array_equal(cc_got, cc_want)


@pytest.mark.slow
def test_dist_cc_overcap_shard_map(monkeypatch):
    """The two-level fold must trace inside shard_map: CC through
    DistEngine on 2 virtual devices with the cap lowered, pallas vs ref
    bit parity."""
    import os
    import subprocess
    import sys
    import textwrap
    code = """
    import numpy as np
    from repro.dist.compat import AxisType, make_mesh
    from repro.graph import rmat, build_layout
    from repro.graph.shard import shard_layout
    from repro.dist.engine import DistEngine
    from repro.apps.cc import cc_program
    import jax.numpy as jnp
    D = 2
    mesh = make_mesh((D,), ("dev",), axis_types=(AxisType.Auto,))
    g = rmat(8, 8, seed=5)
    L = build_layout(g, k=4, edge_tile=64, msg_tile=32)
    SL = shard_layout(L, D)
    assert SL.nv + 1 > 16          # cap lowered to 16 via env below
    N = D * SL.nv
    outs = {}
    for backend in ("ref", "pallas-interpret"):
        eng = DistEngine(SL, cc_program(), mesh, mode="dc",
                         backend=backend)
        assert eng.backend_name == backend
        label = jnp.arange(N, dtype=jnp.uint32)
        frontier = np.zeros(N, bool); frontier[:g.n] = True
        state, _, _ = eng.run({"label": label}, frontier)
        outs[backend] = np.asarray(state["label"])[:g.n]
    assert np.array_equal(outs["ref"], outs["pallas-interpret"])
    print("dist overcap parity ok")
    """
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               REPRO_FOLD_MAX_SEGMENTS="16",
               PYTHONPATH=os.path.join(repo, "src"))
    env.pop("REPRO_KERNEL_BACKEND", None)
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "dist overcap parity ok" in r.stdout
