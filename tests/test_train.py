"""Training stack: optimization works, checkpoints restart (incl. elastic),
data pipeline is step-addressable-deterministic, compression paths run."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AxisType

from repro.dist.sharding import set_activation_mesh
from repro.models.config import ModelConfig
from repro.models.transformer import init_lm
from repro.train import (DataConfig, OptConfig, TokenPipeline, checkpoint,
                         init_opt_state, jit_train_step, make_train_step)

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv=2, d_head=16, d_ff=128, vocab=256,
                  dtype="float32")


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


@pytest.fixture()
def setup():
    params, axes = init_lm(CFG, jax.random.PRNGKey(0))
    mesh = _mesh()
    ocfg = OptConfig(lr=1e-3, warmup=5, total_steps=100,
                     compute_dtype="float32")
    opt = init_opt_state(params, ocfg)
    step, sh = make_train_step(CFG, ocfg, mesh, axes, params,
                               microbatches=2)
    yield params, opt, jit_train_step(step, sh), ocfg
    set_activation_mesh(None)


def test_loss_decreases(setup):
    params, opt, jstep, _ = setup
    pipe = TokenPipeline(DataConfig(vocab=256, seq_len=32, global_batch=8,
                                    seed=7))
    losses = []
    for i in range(25):
        b = {k: jnp.asarray(v) for k, v in pipe.batch_at(i % 3).items()}
        params, opt, m = jstep(params, opt, b)
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0] - 0.5


def test_checkpoint_roundtrip_and_resume(setup):
    params, opt, jstep, _ = setup
    pipe = TokenPipeline(DataConfig(vocab=256, seq_len=32, global_batch=8,
                                    seed=7))
    b = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    for i in range(3):
        params, opt, _ = jstep(params, opt, b)
    d = tempfile.mkdtemp()
    checkpoint.save(d, 3, params, opt)
    assert checkpoint.latest_step(d) == 3

    # continue two trajectories: live vs restored - must be identical
    p1, o1, _ = jstep(jax.tree_util.tree_map(jnp.copy, params),
                      jax.tree_util.tree_map(jnp.copy, opt), b)
    pr, orr, st = checkpoint.restore(d, params, opt)
    p2, o2, _ = jstep(pr, orr, b)
    for a, bb in zip(jax.tree_util.tree_leaves(p1),
                     jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb), atol=1e-7)


def test_checkpoint_atomic_commit():
    d = tempfile.mkdtemp()
    x = {"w": jnp.ones((4,))}
    checkpoint.save(d, 1, x, {"m": x})
    checkpoint.save(d, 2, x, {"m": x})
    assert checkpoint.latest_step(d) == 2
    # partial temp files never pollute LATEST
    names = os.listdir(d)
    assert not [n for n in names if n.endswith(".tmp")]


def test_elastic_reshard_roundtrip():
    """Checkpoint written under one sharding restores under another mesh
    (elastic scaling contract): values must survive exactly."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    params, _ = init_lm(CFG, jax.random.PRNGKey(1))
    opt = {"m": params}
    d = tempfile.mkdtemp()
    checkpoint.save(d, 7, params, opt)
    mesh = _mesh()
    sh = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), params)
    p2, o2, st = checkpoint.restore(d, params, opt, shardings=sh,
                                    opt_shardings={"m": sh})
    assert st == 7
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_int8_compression_error_feedback():
    from repro.train.optimizer import adamw_update
    params = {"w": jnp.ones((32, 32))}
    cfg = OptConfig(lr=1e-2, int8_compress=True, compute_dtype="float32",
                    weight_decay=0.0, clip_norm=1e9)
    st = init_opt_state(params, cfg)
    g = {"w": jnp.full((32, 32), 1e-3)}
    # error feedback accumulates quantization residue, not zero
    _, st2, _ = adamw_update(params, g, st, cfg)
    assert "ef" in st2
    assert float(jnp.abs(st2["ef"]["w"]).max()) >= 0.0
    # repeated tiny grads still move weights eventually (EF releases mass)
    p = params
    for _ in range(5):
        p, st, _ = adamw_update(p, g, st, cfg)
    assert float(jnp.abs(p["w"] - params["w"]).max()) > 0


def test_data_pipeline_deterministic_and_restartable():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=4, seed=3)
    a = TokenPipeline(cfg).batch_at(42)
    b = TokenPipeline(cfg).batch_at(42)    # fresh pipeline, same step
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])
    c = TokenPipeline(cfg).batch_at(43)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token-shifted views of one stream
    cfg2 = DataConfig(vocab=100, seq_len=16, global_batch=4, seed=3)
    d = TokenPipeline(cfg2).batch_at(42)
    np.testing.assert_array_equal(d["tokens"][:, 1:], d["labels"][:, :-1])


def test_lr_schedule():
    from repro.train.optimizer import lr_at
    cfg = OptConfig(lr=1e-3, warmup=10, total_steps=100)
    assert float(lr_at(cfg, jnp.asarray(0))) < 1e-3 / 5
    assert abs(float(lr_at(cfg, jnp.asarray(10))) - 1e-3) < 1e-4
    assert float(lr_at(cfg, jnp.asarray(100))) < 1e-5 + 1e-6
