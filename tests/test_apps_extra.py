"""Beyond-paper applications: SSSP with parents (packed min-monoid) and
Heat-Kernel PageRank (iteration-indexed coefficients + selective continuity,
cited by the paper as a motivating workload)."""
import math

import numpy as np
import pytest
import scipy.sparse as sp
import scipy.sparse.csgraph as csg

from repro.apps import heat_kernel_pr, sssp_with_parents
from repro.graph import build_layout, rmat, to_scipy


def test_sssp_parents_tree():
    g = rmat(9, 8, seed=2, weighted=True)
    L = build_layout(g, k=8, edge_tile=64, msg_tile=32)
    src = int(np.argmax(g.out_degrees()))
    r = sssp_with_parents(L, src)
    ref = csg.shortest_path(to_scipy(g), method="D", indices=src)
    fin = ~np.isinf(ref)
    np.testing.assert_allclose(r["dist"][fin], ref[fin], atol=1e-4)
    assert np.array_equal(np.isinf(r["dist"]), ~fin)
    # every reached vertex's parent edge is tight: d[v] = d[p] + w(p, v)
    indptr, idx, w = g.indptr, g.indices, g.weights
    for v in np.nonzero(fin)[0]:
        if v == src:
            assert r["parent"][v] == src
            continue
        p = r["parent"][v]
        assert p >= 0
        es = idx[indptr[p]:indptr[p + 1]]
        ws = w[indptr[p]:indptr[p + 1]]
        cand = ws[es == v]
        assert len(cand) > 0
        assert abs(r["dist"][p] + cand.min() - r["dist"][v]) < 1e-3


def test_heat_kernel_matches_series_oracle():
    g = rmat(9, 8, seed=1)
    L = build_layout(g, k=8, edge_tile=64, msg_tile=32)
    seed = int(np.argmax(g.out_degrees()))
    t = 5.0
    hk = heat_kernel_pr(L, [seed], t=t, eps=1e-6, max_terms=40)["hkpr"]
    P = to_scipy(g)
    deg = np.maximum(g.out_degrees(), 1)
    Pn = sp.diags(1.0 / deg) @ P
    x = np.zeros(g.n)
    x[seed] = 1.0
    acc = np.zeros(g.n)
    term = x.copy()
    for k in range(40):
        acc += term
        term = (Pn.T @ term) * (t / (k + 1))
    ref = (acc + term) * math.exp(-t)
    np.testing.assert_allclose(hk, ref, atol=1e-6)
    assert 0 < hk.sum() <= 1.0 + 1e-5


def test_heat_kernel_locality():
    """eps-thresholded diffusion stays local (work-efficiency transfer)."""
    g = rmat(10, 8, seed=3)
    L = build_layout(g, k=8, edge_tile=64, msg_tile=32)
    seed = int(np.argmax(g.out_degrees()))
    r = heat_kernel_pr(L, [seed], t=2.0, eps=1e-3, max_terms=20)
    touched = sum(s.dc_bytes + s.sc_bytes for s in r["stats"])
    assert touched < float(L.dc_cost_bytes().sum()) * 20


def test_pagerank_nibble_matches_acl_oracle():
    """PageRank-Nibble vs a sequential Andersen-Chung-Lang lazy-push oracle
    with identical sweep semantics."""
    from repro.apps import pagerank_nibble
    g = rmat(9, 8, seed=1)
    L = build_layout(g, k=8, edge_tile=64, msg_tile=32)
    seed = int(np.argmax(g.out_degrees()))
    alpha, eps = 0.15, 1e-5
    r = pagerank_nibble(L, [seed], alpha=alpha, eps=eps, max_iters=500)
    indptr, idx = g.indptr, g.indices
    deg = g.out_degrees()
    p = np.zeros(g.n)
    rr = np.zeros(g.n)
    rr[seed] = 1.0
    for _ in range(500):
        act = np.nonzero(rr >= eps * np.maximum(deg, 1e-9))[0]
        if len(act) == 0:
            break
        r_act = rr[act].copy()
        p[act] += alpha * r_act
        rr[act] = (1 - alpha) / 2 * r_act
        for v, rv in zip(act, r_act):
            if deg[v] > 0:
                share = (1 - alpha) / 2 * rv / deg[v]
                np.add.at(rr, idx[indptr[v]:indptr[v + 1]], share)
    np.testing.assert_allclose(r["ppr"], p, atol=1e-6)
    assert 0 < r["ppr"].sum() + r["residual"].sum() <= 1 + 1e-5


def test_async_checkpointer():
    import tempfile
    import jax.numpy as jnp
    from repro.train.checkpoint import AsyncCheckpointer, restore
    d = tempfile.mkdtemp()
    ac = AsyncCheckpointer(d)
    params = {"w": jnp.arange(8.0)}
    for step in (1, 2, 3):      # overlapping saves serialize correctly
        ac.save(step, params, {"m": params})
    ac.wait()
    p2, _, st = restore(d, params, {"m": params})
    assert st == 3
    np.testing.assert_array_equal(np.asarray(p2["w"]),
                                  np.asarray(params["w"]))
