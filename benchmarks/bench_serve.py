"""Serving-tier throughput benchmark: batched vs sequential multi-source
queries over one resident layout.

  PYTHONPATH=src python -m benchmarks.bench_serve [--smoke]
      [--scales 10,12] [--batches 1,2,4,8,16] [--backends ref]
      [--out BENCH_serve.json]

The synthetic serving workload is the paper's §5 repeated-query scenario:
one resident partition-centric layout, B concurrent BFS / SSSP queries
differing only in their source vertex.  For each (scale, backend, app,
batch size) the harness times

  * ``seq``     — B sequential single-query runs through one shared,
                  already-compiled Engine (the old ``GraphQueryServer
                  .step()`` behaviour: B full iteration loops), and
  * ``batched`` — the same B queries as ONE fused
                  :meth:`Engine.run_batched` invocation (the compiled DC
                  iteration vmapped over the query axis).

A second sweep measures the semantic cache on Zipf repeat-source traffic
(the skewed query mix the warmer targets), on a *symmetrized* copy of
the graph (landmark seeding's precondition):

  * ``cold``    — a ``semantic=False`` server receives the stream with
                  its result cache cleared first (every distinct source
                  is computed), and
  * ``warmed``  — a semantic server that has already served the source
                  pool once (landmarks + exact results resident) gets
                  the same stream.

Rows land in ``BENCH_serve.json`` at the repo root with the same schema as
``BENCH_kernels.json`` (batch size encoded in the kernel name, e.g.
``serve_bfs_batched_b8``), so ``tools/check_bench_regression.py`` gates
them in CI unchanged.  Each row also records ``batch`` and ``qps``
(queries per second) so the throughput curve can be read off directly.
``--smoke`` (used by the CI serve lane) runs one small scale at best-of-2.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import numpy as np

from repro.apps.bfs import bfs_program
from repro.apps.sssp import sssp_program
from repro.backend import registry
from repro.core.engine import Engine, _next_pow2
from repro.graph import build_layout, rmat, symmetrize

from .common import time_best as _time_best
from .common import write_telemetry

REPO_ROOT = Path(__file__).resolve().parents[1]
APPS = ("bfs", "sssp")


def serving_engine(app: str, layout, backend_name: str) -> Engine:
    """One shared engine per (layout, app): its per-shape jit cache is
    reused across every batch size, like a resident server's would be."""
    program = bfs_program() if app == "bfs" else sssp_program()
    return Engine(layout, program, mode="dc", backend=backend_name)


def bench_app(app: str, layout, eng: Engine, sources, reps: int):
    """(seq_wall, batched_wall) for B queries through the real app entry
    points on one shared engine, compile excluded (one warmup run of each
    path before timing)."""
    from repro.apps.bfs import bfs, bfs_multi
    from repro.apps.sssp import sssp, sssp_multi
    single_fn, multi_fn = ((bfs, bfs_multi) if app == "bfs"
                           else (sssp, sssp_multi))

    def seq():
        for s in sources:
            single_fn(layout, source=s, engine=eng)

    def batched():
        multi_fn(layout, sources, engine=eng)

    seq(); batched()                       # warmup: compile both paths
    return _time_best(seq, reps), _time_best(batched, reps)


def bench_semantic(app: str, layout, B: int, reps: int):
    """(cold_wall, warmed_wall, n_queries) for one Zipf repeat-source
    stream served at ``max_batch=B``.  The warmed server has the 8-source
    pool resident (exact results + captured landmark state) before the
    clock starts; the cold server re-computes it every call."""
    from repro.serve import GraphQuery, GraphQueryServer, ServeConfig

    rng = np.random.default_rng(11)
    pool = rng.integers(0, layout.n, 8)
    stream = [int(pool[min(rng.zipf(1.5) - 1, len(pool) - 1)])
              for _ in range(max(16, 2 * B))]
    qid = iter(range(1 << 20))

    def drain(srv, sources):
        for s in sources:
            srv.submit(GraphQuery(qid=next(qid), app=app,
                                  params={"source": s}))
        srv.run()

    cold_srv = GraphQueryServer(layout, ServeConfig(semantic=False,
                                                    max_batch=B))

    def cold():
        cold_srv.clear_cache()
        drain(cold_srv, stream)

    warm_srv = GraphQueryServer(layout, ServeConfig(max_batch=B,
                                                    cache_size=256))
    drain(warm_srv, [int(s) for s in pool])     # warm the pool

    def warmed():
        drain(warm_srv, stream)

    cold(); warmed()                            # warmup: compile both
    return _time_best(cold, reps), _time_best(warmed, reps), len(stream)


def _serving_layout(g, k: int):
    """Layout with tile geometry proportional to the per-block edge count.

    The static 256/128 defaults are sized for production-scale graphs; on
    the small end of the sweep they pad every non-empty (p, p') block to a
    mostly-empty 256-slot tile, and the tile padding (identical for the
    sequential and batched paths) swamps the signal this benchmark is
    after.  Scaling the tile to ~4x the mean block occupancy keeps the
    padding fraction roughly constant across scales — the same reasoning
    the autotuner's sweep applies, hard-coded so the benchmark is
    deterministic across machines."""
    k = min(k, max(1, g.n))
    edge_tile = min(256, max(16, _next_pow2(4 * g.m // (k * k))))
    return build_layout(g, k=k, edge_tile=edge_tile,
                        msg_tile=max(8, edge_tile // 2))


def run(scales, backends, batches, reps: int, k: int, out_path: Path):
    platform = jax.default_backend()
    results = []
    for scale in scales:
        g = rmat(scale, 8, seed=1, weighted=True)
        layout = _serving_layout(g, k)
        rng = np.random.default_rng(7)
        # sample sources from the giant component's neighbourhood: high-
        # degree vertices, the realistic serving mix (and non-trivial work)
        order = np.argsort(g.out_degrees())[::-1]
        pool = order[:max(64, max(batches))]
        for backend_name in backends:
            if registry.resolve("gather", "min", platform=platform,
                                choice=backend_name).name != backend_name:
                continue               # would silently time the fallback
            for app in APPS:
                eng = serving_engine(app, layout, backend_name)
                for B in batches:
                    sources = rng.choice(pool, size=B, replace=False)
                    sources = [int(s) for s in sources]
                    seq_s, bat_s = bench_app(app, layout, eng,
                                             sources, reps)
                    for variant, wall in (("seq", seq_s),
                                          ("batched", bat_s)):
                        results.append({
                            "kernel": f"serve_{app}_{variant}_b{B}",
                            "monoid": "min", "backend": backend_name,
                            "scale": scale, "n": int(g.n), "m": int(g.m),
                            "batch": B, "wall_s": wall,
                            "qps": B / max(wall, 1e-9),
                        })
                    print(f"scale={scale} backend={backend_name} app={app} "
                          f"B={B}: seq={seq_s*1e3:.1f}ms "
                          f"batched={bat_s*1e3:.1f}ms "
                          f"speedup={seq_s/max(bat_s,1e-9):.2f}x",
                          file=sys.stderr)
        # semantic-cache sweep on the symmetrized graph (the seeding
        # precondition); only the platform-default backend — the server
        # resolves its own engines, the env override in the pallas CI leg
        # would redirect them anyway
        gs = symmetrize(g)
        lays = _serving_layout(gs, k)
        for app in APPS:
            for B in batches:
                cold_s, warm_s, Q = bench_semantic(app, lays, B, reps)
                for variant, wall in (("cold", cold_s),
                                      ("warmed", warm_s)):
                    results.append({
                        "kernel": f"serve_{app}_{variant}_b{B}",
                        "monoid": "min",
                        "backend": registry.default_backend_name(
                            kernel="gather"),
                        "scale": scale, "n": int(gs.n), "m": int(gs.m),
                        "batch": B, "wall_s": wall,
                        "qps": Q / max(wall, 1e-9),
                    })
                print(f"scale={scale} app={app} B={B}: "
                      f"cold={cold_s*1e3:.1f}ms warmed={warm_s*1e3:.1f}ms "
                      f"warm-speedup={cold_s/max(warm_s,1e-9):.2f}x",
                      file=sys.stderr)
    write_telemetry(out_path, results)
    doc = {
        "meta": {
            "platform": platform,
            "jax": jax.__version__,
            "reps": reps,
            "created": time.strftime("%Y-%m-%dT%H:%M:%S"),
        },
        "results": results,
    }
    out_path.write_text(json.dumps(doc, indent=2))
    print(f"wrote {out_path} ({len(results)} rows)", file=sys.stderr)
    return doc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one small scale, best-of-2 (CI serve lane)")
    ap.add_argument("--scales", default=None,
                    help="comma-separated rmat scales (default 8,10)")
    ap.add_argument("--batches", default=None,
                    help="comma-separated batch sizes (default 1,2,4,8,16)")
    ap.add_argument("--backends", default=None,
                    help="comma-separated backend names (default: platform "
                         "default for the gather kernel)")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--out", default=str(REPO_ROOT / "BENCH_serve.json"))
    args = ap.parse_args()

    if args.smoke:
        scales, reps = [8], 2
    else:
        # default includes the smoke scale so the committed baseline
        # always has rows for the CI guard to match against
        scales = [int(s) for s in (args.scales or "8,10").split(",")]
        reps = args.reps
    batches = [int(b) for b in (args.batches or "1,2,4,8,16").split(",")]
    if args.backends:
        backends = args.backends.split(",")
    else:
        backends = [registry.default_backend_name(kernel="gather")]
    run(scales, backends, batches, reps, args.k, Path(args.out))


if __name__ == "__main__":
    main()
