"""Paper Fig. 4: execution time of GPOP vs baseline frameworks.

Columns: GPOP (hybrid), GPOP_SC, GPOP_DC, and the baseline stand-ins
(vc_push ~ Ligra push, vc_pull/ec ~ Ligra pull & X-Stream, spmv ~ GraphMat)
for BFS / PageRank / SSSP / CC / Nibble.  Times are single-host CPU
wall-clock (the cross-implementation *ratios* are the reproduction target;
absolute numbers are CPU-bound).
"""
from __future__ import annotations

import numpy as np

from repro.apps import bfs, connected_components, nibble, pagerank, sssp
from repro.baselines import vc
from repro.graph import rmat

from .common import emit, graphs, layout_for, symmetrize, timed


def run(scale=None):
    from .common import DEFAULT_SCALE
    scale = scale or DEFAULT_SCALE
    rows = []
    for name, g in graphs(scale).items():
        L = layout_for(g)
        src = int(np.argmax(g.out_degrees()))

        rows.append((name, "bfs", "gpop",
                     timed(lambda: bfs(L, src, mode="hybrid"))))
        rows.append((name, "bfs", "gpop_sc",
                     timed(lambda: bfs(L, src, mode="sc"))))
        rows.append((name, "bfs", "gpop_dc",
                     timed(lambda: bfs(L, src, mode="dc"))))
        rows.append((name, "bfs", "vc_push",
                     timed(lambda: vc.bfs_push(g, src))))
        rows.append((name, "bfs", "vc_pull",
                     timed(lambda: vc.bfs_pull(g, src))))

        rows.append((name, "pagerank", "gpop",
                     timed(lambda: pagerank(L, iters=10))))
        rows.append((name, "pagerank", "spmv",
                     timed(lambda: vc.pagerank_spmv(g, iters=10))))

        gs = symmetrize(g)
        Ls = layout_for(gs)
        rows.append((name, "cc", "gpop",
                     timed(lambda: connected_components(Ls))))
        rows.append((name, "cc", "ec_stream",
                     timed(lambda: vc.cc_ec(gs))))

        rows.append((name, "nibble", "gpop",
                     timed(lambda: nibble(L, seeds=[src], eps=1e-3,
                                          max_iters=30))))

    gw = rmat(scale, 16, seed=1, weighted=True)
    Lw = layout_for(gw)
    srcw = int(np.argmax(gw.out_degrees()))
    rows.append((f"rmat{scale}", "sssp", "gpop",
                 timed(lambda: sssp(Lw, srcw, mode="hybrid"))))
    rows.append((f"rmat{scale}", "sssp", "vc_push",
                 timed(lambda: vc.sssp_push(gw, srcw))))

    emit([(g_, a, i, f"{t*1e3:.1f}") for g_, a, i, t in rows],
         ["graph", "algorithm", "impl", "ms"])
    return rows


if __name__ == "__main__":
    run()
