"""Kernel microbenchmark harness: per-backend timings for the PPM kernels.

  PYTHONPATH=src python -m benchmarks.bench_kernels [--smoke]
      [--scales 8,10,12] [--backends ref,pallas-interpret]
      [--out BENCH_kernels.json]

Times one compiled call of each of ``gather`` (segment_combine), ``scatter``
(dc_gather), ``spmv`` (spmv_block), ``fold`` (fold_block — the blocked
segmented fold behind the distributed gather), ``fold2`` (fold_two_level
— the same fold on an over-cap segment count, where the two-level bucketed
kernel runs) and ``fused`` (fused_step — the single-launch fused DC step
that replaces scatter→gather→fold) for every backend the registry can
lower on this platform,
across rmat graph scales, and writes the results to ``BENCH_kernels.json``
at the repo root — the perf-trajectory artifact every hot-path PR
regenerates.  ``--smoke`` (used by CI) runs two small
scales at best-of-2 so the emission path can never silently rot; CI
compares the smoke rows against the committed baseline with
``tools/check_bench_regression.py``.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax

from repro.backend import registry, tuning
from repro.graph import build_layout, rmat

from .common import write_telemetry

REPO_ROOT = Path(__file__).resolve().parents[1]
KERNELS = ("gather", "scatter", "spmv", "fold", "fold2", "fused")


def bench_backend(layout, backend_name: str, platform: str, reps: int):
    """Per-kernel best-of-reps wall times; skips combos the backend cannot
    lower (recording which backend actually ran is the registry's job)."""
    rows = []
    for kernel in KERNELS:
        monoid = "add"
        # fold2 is the registry 'fold' kernel timed in the over-cap
        # (two-level) regime, not a separate registry entry; 'fused'
        # is registry kernel 'fused_dc'
        reg_kernel = ("fused_dc" if kernel == "fused"
                      else "fold" if kernel.startswith("fold") else kernel)
        resolved = registry.resolve(reg_kernel, monoid,
                                    platform=platform, choice=backend_name)
        if resolved.name != backend_name:
            continue                 # would silently time the fallback
        t = tuning.time_layout(layout, backend_name, platform,
                               kernels=(kernel,), reps=reps,
                               monoid=monoid)
        if kernel not in t:
            continue
        rows.append({"kernel": kernel, "monoid": monoid,
                     "backend": backend_name, "wall_s": t[kernel]})
    return rows


def run(scales, backends, reps: int, k: int, out_path: Path) -> dict:
    platform = jax.default_backend()
    results = []
    for scale in scales:
        g = rmat(scale, 8, seed=1)
        layout = build_layout(g, k=min(k, max(1, g.n)))
        for backend_name in backends:
            rows = bench_backend(layout, backend_name, platform, reps)
            for r in rows:
                r.update(scale=scale, n=int(g.n), m=int(g.m),
                         k=int(layout.k), q=int(layout.q),
                         edge_tile=int(layout.edge_tile),
                         msg_tile=int(layout.msg_tile),
                         fold_tile=int(layout.fold_tile),
                         fold_q=int(layout.fold_q))
                results.append(r)
            print(f"scale={scale} backend={backend_name}: "
                  + (", ".join(f"{r['kernel']}={r['wall_s']*1e3:.3f}ms"
                               for r in rows) or "no supported kernels"),
                  file=sys.stderr)
    write_telemetry(out_path, results)
    doc = {
        "meta": {
            "platform": platform,
            "jax": jax.__version__,
            "reps": reps,
            "created": time.strftime("%Y-%m-%dT%H:%M:%S"),
        },
        "results": results,
    }
    out_path.write_text(json.dumps(doc, indent=2))
    print(f"wrote {out_path} ({len(results)} rows)", file=sys.stderr)
    return doc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny scale, 1 rep (CI artifact-emission check)")
    ap.add_argument("--scales", default=None,
                    help="comma-separated rmat scales (default 8,10,12)")
    ap.add_argument("--backends", default=None,
                    help="comma-separated backend names (default: all "
                         "resolvable on this platform)")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--out", default=str(REPO_ROOT / "BENCH_kernels.json"))
    args = ap.parse_args()

    if args.smoke:
        # two scales x best-of-2: enough signal for the CI regression
        # guard's machine calibration without a full bench run
        scales = [6, 8]
        reps = 2
    else:
        scales = [int(s) for s in (args.scales or "8,10,12").split(",")]
        reps = args.reps
    if args.backends:
        backends = args.backends.split(",")
    else:
        platform = jax.default_backend()
        backends = [n for n in registry.available_backends()
                    if registry.BACKENDS[n].supports(platform, "gather",
                                                     "add", "float32")]
    run(scales, backends, reps, args.k, Path(args.out))


if __name__ == "__main__":
    main()
