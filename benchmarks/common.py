"""Shared helpers for the paper-table benchmarks."""
from __future__ import annotations

import time

import numpy as np

from repro.graph import build_layout, from_edges, rmat
from repro.graph import symmetrize as _graph_symmetrize

DEFAULT_SCALE = 12      # 4k vertices / 64k edges: CPU-budget default


def graphs(scale: int = DEFAULT_SCALE, weighted: bool = False):
    """The benchmark graph set: rmat (paper's synthetic family) + a
    uniform-degree graph standing in for the web-crawl family."""
    from repro.graph import uniform_random
    return {
        f"rmat{scale}": rmat(scale, 16, seed=1, weighted=weighted),
        f"uniform{scale}": uniform_random(1 << scale, (1 << scale) * 8,
                                          seed=2, weighted=weighted),
    }


def layout_for(g, k: int = 32):
    return build_layout(g, k=k, edge_tile=256, msg_tile=128)


def symmetrize(g):
    """Delegates to :func:`repro.graph.symmetrize`, which also
    canonicalizes weights (one weight per unordered pair) — the form the
    serve tier's landmark seeding requires."""
    return _graph_symmetrize(g)


def timed(fn, repeat: int = 3):
    fn()                                   # warmup + compile
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def time_best(fn, reps: int) -> float:
    """Best-of-reps wall time; compile excluded by the caller's warmup."""
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def write_telemetry(out_path, results):
    """Emit one schema'd ``bench_row`` event per result row through the
    obs JSONL sink into ``<OUT stem>.telemetry.jsonl`` next to the
    benchmark JSON, and point each row at the sidecar via a
    ``"telemetry"`` key (``tools/check_obs_schema.py`` validates the
    sidecar; ``tools/check_bench_regression.py`` matches rows on
    (kernel, backend, monoid, scale), so the extra key is inert there).
    Returns the sidecar path."""
    from pathlib import Path

    from repro.obs.export import JsonlSink

    out_path = Path(out_path)
    sidecar = out_path.with_suffix(".telemetry.jsonl")
    sidecar.unlink(missing_ok=True)
    with JsonlSink(sidecar) as sink:
        for r in results:
            sink.emit({"event": "bench_row", "ts": time.time(), **r})
            r["telemetry"] = sidecar.name
    return sidecar


def emit(rows, header):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
