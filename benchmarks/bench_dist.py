"""Distributed serving benchmark: batched vs sequential multi-source
queries across the device mesh, and the wire-compression payoff.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python -m benchmarks.bench_dist [--smoke]
      [--scales 8] [--batches 1,4,8,16] [--out BENCH_dist.json]

For each (scale, app, batch size) over a ``D = jax.device_count()`` mesh
the harness times

  * ``seq``          — B sequential :meth:`DistEngine.run` calls (one
                       host-driven loop per query: B× every all_to_all
                       dispatch),
  * ``batched``      — the same B queries as ONE fused
                       :meth:`DistEngine.run_batched` invocation (the bin
                       exchange carries ``[B, D, S]`` per collective;
                       packed frontier-bitmap flags), and
  * ``batched_wire`` — the fused batch with ``wire_bf16=True`` on top
                       (f32 monoids only: the value payload halves).

Every row records the *analytic* per-step per-device all_to_all payload
(``wire_bytes``, from :func:`repro.dist.engine.dc_wire_bytes`) next to the
uncompressed bool-lane baseline (``wire_bytes_raw``), so the wire
reduction is read off the JSON directly.  Rows share the
``BENCH_kernels.json`` schema (batch in the kernel name, e.g.
``dist_bfs_batched_b8``) and are gated by
``tools/check_bench_regression.py`` in CI unchanged.  ``--smoke`` (the CI
dist-serve lane) runs one scale at best-of-2.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import numpy as np

from .common import time_best as _time_best
from .common import write_telemetry

REPO_ROOT = Path(__file__).resolve().parents[1]
APPS = ("bfs", "sssp")


def _engines(app: str, sharded, mesh, backend):
    """(plain, wired) shared engines for one app — plain ships f32 values
    + bitmap flags, wired adds the bf16 value wire (f32 monoids only)."""
    from repro.apps.bfs import bfs_program
    from repro.apps.sssp import sssp_program
    from repro.dist.engine import DistEngine
    program = bfs_program() if app == "bfs" else sssp_program()
    plain = DistEngine(sharded, program, mesh, mode="dc", backend=backend)
    wired = DistEngine(sharded, (bfs_program() if app == "bfs"
                                 else sssp_program()), mesh, mode="dc",
                       backend=backend, wire_bf16=True)
    return plain, wired


def bench_app(app: str, layout, engines, sources, reps: int):
    """{variant: wall_s} for B queries, compile excluded (one warmup run
    of each path before timing)."""
    from repro.apps.bfs import bfs, bfs_multi
    from repro.apps.sssp import sssp, sssp_multi
    single_fn, multi_fn = ((bfs, bfs_multi) if app == "bfs"
                           else (sssp, sssp_multi))
    plain, wired = engines

    def seq():
        for s in sources:
            single_fn(layout, source=s, engine=plain)

    def batched():
        multi_fn(layout, sources, engine=plain)

    def batched_wire():
        multi_fn(layout, sources, engine=wired)

    seq(); batched(); batched_wire()       # warmup: compile all paths
    return {"seq": _time_best(seq, reps),
            "batched": _time_best(batched, reps),
            "batched_wire": _time_best(batched_wire, reps)}


def run(scales, batches, reps: int, k: int, out_path: Path, backend=None):
    from repro.dist.compat import AxisType, make_mesh
    from repro.dist.engine import dc_wire_bytes
    from repro.graph import build_layout, rmat
    from repro.graph.shard import shard_layout

    D = jax.device_count()
    mesh = make_mesh((D,), ("dev",), axis_types=(AxisType.Auto,))
    k = max(k, D)
    results = []
    for scale in scales:
        g = rmat(scale, 8, seed=1, weighted=True)
        layout = build_layout(g, k=k, edge_tile=32, msg_tile=16)
        sharded = shard_layout(layout, D)
        meta = dict(S=sharded.S, D=D)
        rng = np.random.default_rng(7)
        order = np.argsort(g.out_degrees())[::-1]
        pool = order[:max(64, max(batches))]
        for app in APPS:
            engines = _engines(app, sharded, mesh, backend)
            itemsize = 4                   # both monoids carry 4B values
            compress = engines[1].wire_compressed
            for B in batches:
                sources = [int(s) for s in
                           rng.choice(pool, size=B, replace=False)]
                walls = bench_app(app, layout, engines, sources, reps)
                raw = dc_wire_bytes(meta, itemsize, compressed=False,
                                    wire_bitmap=False, batch=B)
                wb = {"seq": dc_wire_bytes(meta, itemsize, batch=1),
                      "batched": dc_wire_bytes(meta, itemsize, batch=B),
                      "batched_wire": dc_wire_bytes(
                          meta, itemsize, compressed=compress, batch=B)}
                for variant, wall in walls.items():
                    results.append({
                        "kernel": f"dist_{app}_{variant}_b{B}",
                        "monoid": "min", "backend": "dist",
                        "scale": scale, "n": int(g.n), "m": int(g.m),
                        "devices": D, "batch": B, "wall_s": wall,
                        "qps": B / max(wall, 1e-9),
                        "wire_bytes": wb[variant],
                        "wire_bytes_raw": raw,
                    })
                print(f"scale={scale} app={app} D={D} B={B}: "
                      f"seq={walls['seq']*1e3:.1f}ms "
                      f"batched={walls['batched']*1e3:.1f}ms "
                      f"wire={walls['batched_wire']*1e3:.1f}ms "
                      f"speedup={walls['seq']/max(walls['batched'],1e-9):.2f}x "
                      f"bytes {raw}->{wb['batched_wire']}",
                      file=sys.stderr)
    write_telemetry(out_path, results)
    doc = {
        "meta": {
            "platform": jax.default_backend(),
            "jax": jax.__version__,
            "devices": D,
            "reps": reps,
            "created": time.strftime("%Y-%m-%dT%H:%M:%S"),
        },
        "results": results,
    }
    out_path.write_text(json.dumps(doc, indent=2))
    print(f"wrote {out_path} ({len(results)} rows)", file=sys.stderr)
    return doc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one small scale, best-of-2 (CI dist-serve lane)")
    ap.add_argument("--scales", default=None,
                    help="comma-separated rmat scales (default 8)")
    ap.add_argument("--batches", default=None,
                    help="comma-separated batch sizes (default 1,4,8,16)")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--out", default=str(REPO_ROOT / "BENCH_dist.json"))
    args = ap.parse_args()
    if args.smoke:
        scales, reps = [8], 2
    else:
        scales = [int(s) for s in (args.scales or "8").split(",")]
        reps = args.reps
    batches = [int(b) for b in (args.batches or "1,4,8,16").split(",")]
    run(scales, batches, reps, args.k, Path(args.out))


if __name__ == "__main__":
    main()
