"""Paper Figs. 5/6: strong scaling of BFS and PageRank.

The paper scales OS threads on fixed input; the TPU-mapping analogue is the
device count.  This host has ONE physical core, so wall-clock cannot show
speedup; the reproduction instead reports the *per-device work and wire
bytes* of the distributed engine as the device count scales (the quantities
that determine scaling on real hardware), measured from real multi-device
executions in subprocesses.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from .common import emit

_CODE = """
import os, json, time
import numpy as np
import jax, jax.numpy as jnp
from repro.dist.compat import AxisType, make_mesh
from repro.graph import rmat, build_layout
from repro.graph.shard import shard_layout
from repro.dist.engine import DistEngine
from repro.apps.bfs import bfs_program
from repro.apps.pagerank import pagerank_program

D = {D}
mesh = make_mesh((D,), ("dev",), axis_types=(AxisType.Auto,))
g = rmat({scale}, 16, seed=1)
L = build_layout(g, k=max(16, 4*D), edge_tile=64, msg_tile=32)
SL = shard_layout(L, D)
N = D * SL.nv
src = int(np.argmax(g.out_degrees()))

prog = bfs_program()
parent = np.full(N, -1, np.int32); parent[src] = src
level = np.full(N, -1, np.int32); level[src] = 0
vid = np.arange(N, dtype=np.uint32)
f = np.zeros(N, bool); f[src] = True
eng = DistEngine(SL, prog, mesh, mode="hybrid")
st = {{"parent": parent, "level": level, "vid": vid}}
_,_,stats = eng.run(st, f)          # warm (compiles)
t0 = time.time()
_,_,stats = eng.run(st, f)
bfs_t = time.time() - t0

prog = pagerank_program(g.n)
pr0 = np.zeros(N, np.float32); pr0[:g.n] = 1.0/g.n
deg = np.zeros(N, np.float32); deg[:L.n_pad] = SL.deg[:L.n_pad]
f = np.zeros(N, bool); f[:g.n] = True
eng = DistEngine(SL, prog, mesh, mode="dc")
st0 = {{"pr": pr0, "deg": deg}}
eng.run(st0, f, max_iters=3, until_empty=False)
t0 = time.time()
eng.run(st0, f, max_iters=3, until_empty=False)
pr_t = (time.time() - t0) / 3
print(json.dumps(dict(D=D, bfs_s=bfs_t, pr_iter_s=pr_t,
                      edges_per_dev=int(SL.ne_d),
                      dc_slots_per_dev=int(D*SL.S))))
"""


def run(scale: int = 12, devices=(1, 2, 4, 8)):
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    rows = []
    for D in devices:
        env = dict(os.environ,
                   XLA_FLAGS=f"--xla_force_host_platform_device_count={D}",
                   PYTHONPATH=os.path.join(repo, "src"))
        r = subprocess.run(
            [sys.executable, "-c",
             textwrap.dedent(_CODE.format(D=D, scale=scale))],
            capture_output=True, text=True, env=env, timeout=1200)
        if r.returncode != 0:
            rows.append((D, "FAIL", "", "", ""))
            continue
        d = json.loads(r.stdout.strip().splitlines()[-1])
        rows.append((D, f"{d['bfs_s']*1e3:.0f}", f"{d['pr_iter_s']*1e3:.0f}",
                     d["edges_per_dev"], d["dc_slots_per_dev"]))
    emit(rows, ["devices", "bfs_ms", "pr_iter_ms", "edges_per_dev",
                "dc_slots_per_dev"])
    return rows


if __name__ == "__main__":
    run()
