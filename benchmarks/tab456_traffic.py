"""Paper Tables 4-6: cache misses -> modeled DRAM/HBM traffic.

No hardware counters exist on this CPU stand-in, so the analog is the
engine's Eq. 1 bytes model (per-iteration, per-mode — the same quantity the
paper's L2-miss tables proxy) for GPOP, vs the structural traffic of each
baseline: vc_push reads E_a edges + random vertex values (a full cache line
per touched vertex - the paper's Fig. 1 point), pull/spmv stream all E edges
every iteration.
"""
from __future__ import annotations

import numpy as np

from repro.apps import bfs, connected_components, pagerank, sssp
from repro.graph import rmat

from .common import emit, graphs, layout_for, symmetrize

D_I = D_V = 4
CACHE_LINE = 64         # the paper's random-access penalty unit


def _gpop_bytes(stats):
    return sum(s.dc_bytes + s.sc_bytes for s in stats)


def _push_bytes(stats_iters_eactive, stats_iters_nactive):
    # per active edge: edge read + random read-modify-write of dst value
    return sum(e * (D_I + 2 * CACHE_LINE) for e in stats_iters_eactive)


def run(scale=None):
    from .common import DEFAULT_SCALE
    scale = scale or DEFAULT_SCALE
    rows = []
    for name, g in graphs(scale).items():
        L = layout_for(g)
        src = int(np.argmax(g.out_degrees()))

        # --- PageRank (table 4): 10 iterations, all vertices active ---
        iters = 10
        gpop = float(L.dc_cost_bytes().sum()) * iters
        spmv = iters * (g.m * (D_I + D_V) + g.m * CACHE_LINE)  # random x[]
        rows.append((name, "pagerank", f"{gpop/1e6:.1f}",
                     f"{spmv/1e6:.1f}", f"{spmv/gpop:.2f}"))

        # --- CC / label prop (table 5) ---
        gs = symmetrize(g)
        Ls = layout_for(gs)
        r = connected_components(Ls)
        gpop = _gpop_bytes(r["stats"])
        ec = sum(1 for _ in r["stats"]) * (gs.m * (D_I + D_V)
                                           + gs.m * CACHE_LINE)
        rows.append((name, "cc", f"{gpop/1e6:.1f}", f"{ec/1e6:.1f}",
                     f"{ec/gpop:.2f}"))

    # --- SSSP (table 6) ---
    gw = rmat(scale, 16, seed=1, weighted=True)
    Lw = layout_for(gw)
    srcw = int(np.argmax(gw.out_degrees()))
    r = sssp(Lw, srcw, mode="hybrid")
    gpop = _gpop_bytes(r["stats"])
    push = _push_bytes([s.e_active for s in r["stats"]], None)
    rows.append((f"rmat{scale}", "sssp", f"{gpop/1e6:.1f}",
                 f"{push/1e6:.1f}", f"{push/gpop:.2f}"))

    emit(rows, ["graph", "algorithm", "gpop_MB", "baseline_MB", "ratio"])
    return rows


if __name__ == "__main__":
    run()
