"""Paper Fig. 9: per-iteration execution time of GPOP vs GPOP_SC vs GPOP_DC.

The reproduction target is the crossover structure: SC wins on sparse
frontiers, DC wins on dense ones, and the hybrid engine tracks the lower
envelope via the Eq. 1 per-partition decision.  Reported per iteration:
wall time, modeled bytes, and the mode split.
"""
from __future__ import annotations

import numpy as np

from repro.apps import bfs, connected_components, sssp
from repro.graph import rmat

from .common import emit, layout_for, symmetrize


def run(scale=None):
    from .common import DEFAULT_SCALE
    scale = scale or DEFAULT_SCALE
    g = rmat(scale, 16, seed=1)
    L = layout_for(g)
    src = int(np.argmax(g.out_degrees()))
    rows = []
    for mode in ("hybrid", "sc", "dc"):
        stats = bfs(L, src, mode=mode)["stats"]
        for s in stats:
            rows.append(("bfs", mode, s.it, s.n_active, s.e_active,
                         s.dc_parts, s.sc_parts,
                         f"{(s.dc_bytes + s.sc_bytes)/1e6:.2f}",
                         f"{s.wall_s*1e3:.1f}"))
    gs = symmetrize(g)
    Ls = layout_for(gs)
    for mode in ("hybrid", "sc", "dc"):
        stats = connected_components(Ls, mode=mode)["stats"]
        for s in stats:
            rows.append(("cc", mode, s.it, s.n_active, s.e_active,
                         s.dc_parts, s.sc_parts,
                         f"{(s.dc_bytes + s.sc_bytes)/1e6:.2f}",
                         f"{s.wall_s*1e3:.1f}"))
    emit(rows, ["algorithm", "mode", "iter", "n_active", "e_active",
                "dc_parts", "sc_parts", "modeled_MB", "wall_ms"])

    # validation of the analytical model (paper §6.2.3): hybrid's modeled
    # bytes never exceed either pure mode's bytes
    for alg, Lx, runner in (("bfs", L, lambda m: bfs(L, src, mode=m)),
                            ):
        by = {m: sum(s.dc_bytes + s.sc_bytes for s in runner(m)["stats"])
              for m in ("hybrid", "sc", "dc")}
        assert by["hybrid"] <= min(by["sc"], by["dc"]) * 1.001, by
        print(f"# {alg}: hybrid bytes {by['hybrid']/1e6:.1f}MB <= "
              f"min(SC {by['sc']/1e6:.1f}, DC {by['dc']/1e6:.1f}) OK")
    return rows


if __name__ == "__main__":
    run()
