"""Paper Figs. 7/8: weak scaling — problem size grows with the resource.

Single-host analogue: rmat scale sweep at fixed engine config; reported
per-edge processing rate for BFS (frontier-driven) and PageRank (DC mode),
which is the flat-line the paper's weak-scaling argues for.
"""
from __future__ import annotations

import numpy as np

from repro.apps import bfs, pagerank
from repro.graph import rmat

from .common import emit, layout_for, timed


def run(scales=(10, 11, 12, 13)):
    rows = []
    for s in scales:
        g = rmat(s, 16, seed=1)
        L = layout_for(g)
        src = int(np.argmax(g.out_degrees()))
        t_bfs = timed(lambda: bfs(L, src, mode="hybrid"), repeat=2)
        t_pr = timed(lambda: pagerank(L, iters=5), repeat=2) / 5
        rows.append((f"rmat{s}", g.m, f"{t_bfs*1e3:.0f}",
                     f"{g.m/t_bfs/1e6:.1f}", f"{t_pr*1e3:.0f}",
                     f"{g.m/t_pr/1e6:.1f}"))
    emit(rows, ["graph", "edges", "bfs_ms", "bfs_Medges_s",
                "pr_iter_ms", "pr_Medges_s"])
    return rows


if __name__ == "__main__":
    run()
