"""Dynamic-graph delta benchmark: dirty-partition relayout vs full
rebuild, and incremental recompute vs cold convergence.

  PYTHONPATH=src python -m benchmarks.bench_delta [--smoke]
      [--scales 10,12] [--fracs 0.05,0.1,0.25] [--out BENCH_delta.json]

For each scale an rmat graph is laid out with ``k`` partitions, then a
batch of edge insertions confined to ``ceil(frac * k)`` partitions (both
endpoints — so the dirty fraction is controlled) is applied two ways:

  * ``delta_relayout_p<pct>`` — :func:`repro.graph.delta.apply_delta`:
    only the dirty partitions' CSR rows, scatter slots and gather bins
    are recomputed; everything else is sliced out of the old layout.
  * ``delta_rebuild_p<pct>``  — the reference path: edit the edge list
    (``DeltaBuffer.edit_graph``) and :func:`build_layout` from scratch.

The two produce bit-identical layouts (tests/test_delta.py), so the gap
is pure relayout work.  The claim the committed baseline pins down: at a
<= 10% dirty fraction the scoped relayout beats the full rebuild.

A second pair times closing the loop on the result side, on the
symmetrized graph:

  * ``delta_cc_cold``   — connected components from scratch on the
    post-delta layout;
  * ``delta_cc_resume`` — the same fixpoint restarted from the pre-delta
    labels with ``DeltaBuffer.touched()`` as the frontier (exact for the
    min monoid under insertion-only deltas).

Rows land in ``BENCH_delta.json`` with the ``BENCH_kernels.json`` schema
(``monoid``/``backend``/``scale`` keys), so
``tools/check_bench_regression.py`` gates them in CI unchanged.
``--smoke`` (the CI serve lane) runs one scale at best-of-2.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import numpy as np

from repro.backend import registry
from repro.core.engine import _next_pow2
from repro.graph import (DeltaBuffer, apply_delta, build_layout, rmat,
                         symmetrize)

from .common import time_best as _time_best
from .common import write_telemetry

REPO_ROOT = Path(__file__).resolve().parents[1]


def confined_delta(layout, frac: float, n_ops: int, rng,
                   symmetric: bool = False) -> DeltaBuffer:
    """``n_ops`` edge insertions with BOTH endpoints inside the first
    ``ceil(frac * k)`` partitions, so exactly that fraction of the
    layout is dirty."""
    k, q, n = layout.k, layout.q, layout.n
    dirty_k = max(1, int(np.ceil(frac * k)))
    hi = min(n, dirty_k * q)
    d = DeltaBuffer.for_layout(layout)
    for _ in range(n_ops):
        u = int(rng.integers(0, hi))
        v = int(rng.integers(0, hi))
        w = float(rng.random() + 0.1) if layout.weighted else None
        d.insert(u, v, w)
        if symmetric:
            d.insert(v, u, w)
    return d


def bench_relayout(g, layout, frac: float, reps: int, rng):
    """(relayout_wall, rebuild_wall, dirty_parts) for one confined
    insertion batch."""
    d = confined_delta(layout, frac, n_ops=64, rng=rng)
    kw = dict(k=layout.k, edge_tile=layout.edge_tile,
              msg_tile=layout.msg_tile, fold_tile=layout.fold_tile,
              fold_q=layout.fold_q)

    def relayout():
        apply_delta(layout, d)

    def rebuild():
        build_layout(d.edit_graph(g), **kw)

    relayout(); rebuild()                       # warm any lazy imports
    return (_time_best(relayout, reps), _time_best(rebuild, reps),
            len(d.dirty_partitions()))


def bench_cc_resume(layout, frac: float, reps: int, rng):
    """(resume_wall, cold_wall) for connected components after a
    symmetric confined insertion batch."""
    from repro.apps import connected_components

    d = confined_delta(layout, frac, n_ops=32, rng=rng, symmetric=True)
    new_layout = apply_delta(layout, d)
    old_labels = connected_components(layout)["label"]
    touched = d.touched()

    def cold():
        connected_components(new_layout)

    def resume():
        connected_components(new_layout, resume_labels=old_labels,
                             touched=touched)

    cold(); resume()                            # warmup: compile both
    return _time_best(resume, reps), _time_best(cold, reps)


def _delta_layout(g, k: int):
    """Tile geometry proportional to the per-block edge count (same
    reasoning as bench_serve's _serving_layout): the static 256-slot
    default pads every non-empty (p, p') block of a small graph to a
    mostly-empty tile, and the padded-bin memcpy — identical work for
    relayout and rebuild — swamps the dirty-vs-full signal this
    benchmark is after."""
    k = min(k, max(1, g.n))
    edge_tile = min(256, max(16, _next_pow2(4 * g.m // (k * k))))
    return build_layout(g, k=k, edge_tile=edge_tile,
                        msg_tile=max(8, edge_tile // 2))


def run(scales, fracs, reps: int, k: int, out_path: Path):
    platform = jax.default_backend()
    results = []
    for scale in scales:
        g = rmat(scale, 8, seed=1, weighted=True)
        layout = _delta_layout(g, k)
        rng = np.random.default_rng(3)
        for frac in fracs:
            re_s, rb_s, dirty = bench_relayout(g, layout, frac, reps, rng)
            pct = int(round(frac * 100))
            for variant, wall in (("relayout", re_s), ("rebuild", rb_s)):
                results.append({
                    "kernel": f"delta_{variant}_p{pct}",
                    "monoid": "min", "backend": "host",
                    "scale": scale, "n": int(g.n), "m": int(g.m),
                    "dirty_parts": dirty, "k": int(layout.k),
                    "wall_s": wall,
                })
            print(f"scale={scale} dirty={pct}% ({dirty}/{layout.k} parts): "
                  f"relayout={re_s*1e3:.1f}ms rebuild={rb_s*1e3:.1f}ms "
                  f"speedup={rb_s/max(re_s,1e-9):.2f}x", file=sys.stderr)
        # incremental recompute on the symmetrized graph (CC needs the
        # undirected view); smallest dirty fraction = the serving case
        gs = symmetrize(g)
        lays = _delta_layout(gs, k)
        backend = registry.default_backend_name(kernel="gather")
        res_s, cold_s = bench_cc_resume(lays, min(fracs), reps, rng)
        for variant, wall in (("resume", res_s), ("cold", cold_s)):
            results.append({
                "kernel": f"delta_cc_{variant}",
                "monoid": "min", "backend": backend,
                "scale": scale, "n": int(gs.n), "m": int(gs.m),
                "wall_s": wall,
            })
        print(f"scale={scale} cc: resume={res_s*1e3:.1f}ms "
              f"cold={cold_s*1e3:.1f}ms "
              f"speedup={cold_s/max(res_s,1e-9):.2f}x", file=sys.stderr)
    write_telemetry(out_path, results)
    doc = {
        "meta": {
            "platform": platform,
            "jax": jax.__version__,
            "reps": reps,
            "created": time.strftime("%Y-%m-%dT%H:%M:%S"),
        },
        "results": results,
    }
    out_path.write_text(json.dumps(doc, indent=2))
    print(f"wrote {out_path} ({len(results)} rows)", file=sys.stderr)
    return doc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one scale, best-of-2 (CI serve lane)")
    ap.add_argument("--scales", default=None,
                    help="comma-separated rmat scales (default 10,12)")
    ap.add_argument("--fracs", default=None,
                    help="comma-separated dirty fractions "
                         "(default 0.05,0.1,0.25)")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--k", type=int, default=64)
    ap.add_argument("--out", default=str(REPO_ROOT / "BENCH_delta.json"))
    args = ap.parse_args()

    if args.smoke:
        scales, reps = [12], 2
    else:
        # default includes the smoke scale so the committed baseline
        # always has rows for the CI guard to match against
        scales = [int(s) for s in (args.scales or "10,12").split(",")]
        reps = args.reps
    fracs = [float(f) for f in (args.fracs or "0.05,0.1,0.25").split(",")]
    run(scales, fracs, reps, args.k, Path(args.out))


if __name__ == "__main__":
    main()
