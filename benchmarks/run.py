"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig4,...]

Prints CSV sections; the dry-run roofline tables live in results/dryrun and
EXPERIMENTS.md (they need the 512-device AOT environment, not this harness).
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--scale", type=int, default=None,
                    help="rmat scale for graph benchmarks")
    ap.add_argument("--skip-scaling", action="store_true",
                    help="skip the multi-device subprocess benchmarks")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from . import (fig4_exec_time, fig56_strong_scaling, fig78_weak_scaling,
                   fig9_modes, tab456_traffic)
    sections = [
        ("fig4_exec_time", lambda: fig4_exec_time.run(args.scale)),
        ("tab456_traffic", lambda: tab456_traffic.run(args.scale)),
        ("fig9_modes", lambda: fig9_modes.run(args.scale)),
        ("fig78_weak_scaling", lambda: fig78_weak_scaling.run()),
    ]
    if not args.skip_scaling:
        sections.append(("fig56_strong_scaling",
                         lambda: fig56_strong_scaling.run()))
    for name, fn in sections:
        if only and name not in only:
            continue
        print(f"\n=== {name} ===")
        t0 = time.time()
        fn()
        print(f"# section wall: {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == '__main__':
    main()
