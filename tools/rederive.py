"""Re-derive roofline terms for every cell from the saved HLO dumps —
no recompilation (analysis-model changes apply retroactively).

  PYTHONPATH=src python tools/rederive.py
"""
import glob
import gzip
import json
import os

from repro.hlo_cost import analyze
from repro.roofline import roofline_terms

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS = os.path.join(ROOT, "results", "dryrun")


def main():
    n = 0
    for jf in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        if "FAILED" in jf:
            continue
        tag = os.path.basename(jf)[:-5]
        r = json.load(open(jf))
        # reconstruct the hlo dump name the way dryrun.summarize builds it
        mesh_tag = "pod2x16x16" if r["chips"] == 512 else "pod16x16"
        hlo_tag = f"{r['arch']}_{r['shape']}_{mesh_tag}"
        if r.get("variant"):
            hlo_tag += f"_v_{r['variant']}"
        hf = os.path.join(RESULTS, "hlo", hlo_tag + ".hlo.gz")
        if not os.path.exists(hf):
            hf = os.path.join(RESULTS, "hlo", tag + ".hlo.gz")
        if not os.path.exists(hf):
            print("no hlo for", tag)
            continue
        hlo = gzip.open(hf, "rt").read()
        walk = analyze(hlo, default_group=r["chips"])
        r["flops_per_dev"] = float(walk["flops"])
        r["bytes_per_dev"] = float(walk["bytes"])
        r["wire_bytes_per_dev"] = float(walk["wire_bytes"])
        r["coll_counts"] = walk["coll_counts"]
        r["roofline"] = roofline_terms(walk["flops"], walk["bytes"],
                                       walk["wire_bytes"])
        mf = r.get("model_flops_total")
        r["useful_ratio"] = (mf / (walk["flops"] * r["chips"])
                             if mf and walk["flops"] else None)
        json.dump(r, open(jf, "w"), indent=1, default=str)
        n += 1
    print(f"re-derived {n} cells")


if __name__ == "__main__":
    main()
