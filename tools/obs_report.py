"""Render the per-iteration telemetry table from an obs JSONL export.

  PYTHONPATH=src python tools/obs_report.py EVENTS.jsonl
  PYTHONPATH=src python tools/obs_report.py --demo [--sink out.jsonl]

Given a JSONL event file (``repro.obs.export.write_jsonl`` or the
``REPRO_OBS_SINK`` stream), prints, per engine run:

  * the ``engine_iter`` table — iteration, mode decision (dc / sc /
    hybrid and the per-partition split), active vertex/edge counts, the
    wire bytes (analytic all_to_all payload for dist steps, the Eq. 1
    modeled dc+sc traffic for single-device steps), and step wall time;
  * the ``batch_iter`` table — live lanes, compiled width, union-frontier
    active count and step wall per batched superstep;
  * a one-line summary per serve / fused / bench event family.

``--demo`` runs a small self-contained workload first (BFS + unfused
PageRank on an rmat graph, then a batch of GraphQueryServer queries),
with telemetry forced ON, and reports the collected events — the CI
serve lane uses it as the obs smoke workload.  ``--sink`` additionally
streams every event to the given JSONL path (the artifact
``tools/check_obs_schema.py`` then validates).
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]
if str(REPO_ROOT / "src") not in sys.path:
    sys.path.insert(0, str(REPO_ROOT / "src"))


def _fmt_row(cols, widths):
    return "  ".join(str(c).rjust(w) for c, w in zip(cols, widths))


def _wire_of(e) -> int:
    if "wire_bytes" in e:
        return int(e["wire_bytes"])
    return int(e.get("dc_bytes", 0) + e.get("sc_bytes", 0))


def render(events) -> str:
    lines = []
    iters = [e for e in events if e.get("event") == "engine_iter"]
    # one table per (engine, program) run, in first-seen order
    groups: dict = {}
    for e in iters:
        groups.setdefault((e.get("engine", "?"), e.get("program", "?")),
                          []).append(e)
    for (engine, program), evs in groups.items():
        lines.append(f"== engine={engine} program={program} "
                     f"({len(evs)} iterations) ==")
        header = ("it", "mode", "dc/sc", "n_active", "e_active",
                  "wire_B", "wall_ms")
        rows = []
        for e in sorted(evs, key=lambda e: e.get("it", 0)):
            parts = (f"{e['dc_parts']}/{e['sc_parts']}"
                     if "dc_parts" in e and "sc_parts" in e else "-")
            rows.append((e.get("it", "?"), e.get("mode", "?"), parts,
                         e.get("n_active", "?"), e.get("e_active", "?"),
                         _wire_of(e), f"{e.get('wall_s', 0) * 1e3:.2f}"))
        widths = [max(len(str(h)), *(len(str(r[i])) for r in rows))
                  for i, h in enumerate(header)]
        lines.append(_fmt_row(header, widths))
        for r in rows:
            lines.append(_fmt_row(r, widths))
        tot = sum(e.get("wall_s", 0) for e in evs)
        lines.append(f"   total {tot * 1e3:.2f} ms, "
                     f"{sum(_wire_of(e) for e in evs)} wire bytes")
        lines.append("")

    batched = [e for e in events if e.get("event") == "batch_iter"]
    bgroups: dict = {}
    for e in batched:
        bgroups.setdefault((e.get("engine", "?"), e.get("program", "?")),
                           []).append(e)
    for (engine, program), evs in bgroups.items():
        lines.append(f"== batched engine={engine} program={program} "
                     f"({len(evs)} supersteps) ==")
        header = ("it", "lanes", "width", "n_active", "wall_ms")
        rows = [(e.get("it", "?"), e.get("lanes_active", "?"),
                 e.get("width", "?"), e.get("n_active", "?"),
                 f"{e.get('wall_s', 0) * 1e3:.2f}")
                for e in sorted(evs, key=lambda e: e.get("it", 0))]
        widths = [max(len(str(h)), *(len(str(r[i])) for r in rows))
                  for i, h in enumerate(header)]
        lines.append(_fmt_row(header, widths))
        for r in rows:
            lines.append(_fmt_row(r, widths))
        lines.append("")

    for kind, fmt in (
            ("fused_run", lambda e: f"engine={e.get('engine')} "
             f"program={e.get('program')} iters={e.get('iters')} "
             f"wall={e.get('wall_s', 0) * 1e3:.2f}ms"),
            ("lane_compaction", lambda e: f"program={e.get('program')} "
             f"it={e.get('it')} lanes={e.get('lanes_active')} -> "
             f"width={e.get('width')} (of {e.get('batch')})"),
            ("serve_batch", lambda e: f"app={e.get('app')} "
             f"batch={e.get('batch')} distinct={e.get('distinct_sources')} "
             f"width={e.get('width')} wall={e.get('wall_s', 0)*1e3:.2f}ms"),
            ("serve_query", lambda e: f"app={e.get('app')} "
             f"cached={e.get('cached')} "
             f"wall={e.get('wall_s', 0) * 1e3:.2f}ms"),
            ("bench_row", lambda e: f"kernel={e.get('kernel')} "
             f"backend={e.get('backend')} "
             f"wall={e.get('wall_s', 0) * 1e3:.3f}ms")):
        evs = [e for e in events if e.get("event") == kind]
        if evs:
            lines.append(f"== {kind} ({len(evs)}) ==")
            lines.extend("   " + fmt(e) for e in evs)
            lines.append("")
    return "\n".join(lines)


def demo():
    """BFS + unfused PageRank + a served query batch, telemetry forced on
    (PageRank's default fused loop records a single fused_run event; the
    per-iteration table wants the host-driven loop, hence fused=False)."""
    import numpy as np

    from repro import obs
    from repro.apps import bfs, pagerank
    from repro.graph import build_layout, rmat
    from repro.serve.engine import GraphQuery, GraphQueryServer

    obs.set_enabled(True)
    obs.reset()
    g = rmat(9, 8, seed=1)
    layout = build_layout(g, k=8, edge_tile=64, msg_tile=32)
    bfs(layout, source=int(np.argmax(g.out_degrees())))
    pagerank(layout, iters=5, fused=False)
    srv = GraphQueryServer(layout)
    for i, s in enumerate([0, 1, 2, 3, 0]):
        srv.submit(GraphQuery(qid=i, app="bfs", params={"source": int(s)}))
    srv.run()
    # a repeat of an answered query: exercises the LRU hit path
    srv.submit(GraphQuery(qid=99, app="bfs", params={"source": 0}))
    srv.run()
    print(f"demo: {len(obs.events())} events, "
          f"{len(obs.cost_samples())} cost samples "
          f"(cache hits={srv.cache_hits} misses={srv.cache_misses})",
          file=sys.stderr)
    return obs.events()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*", help="JSONL event files")
    ap.add_argument("--demo", action="store_true",
                    help="run the built-in workload and report it")
    ap.add_argument("--sink", default=None,
                    help="with --demo: also stream events to this JSONL")
    args = ap.parse_args(argv)
    if not args.demo and not args.files:
        ap.error("need JSONL files or --demo")

    events = []
    if args.demo:
        import os
        if args.sink:
            # the streaming sink must exist before the workload runs
            os.environ["REPRO_OBS_SINK"] = args.sink
            Path(args.sink).unlink(missing_ok=True)
            from repro import obs
            obs.registry().set_sink(args.sink)
        events.extend(demo())
    for fname in args.files:
        from repro.obs.export import read_jsonl
        events.extend(read_jsonl(fname))
    print(render(events))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
