"""Render EXPERIMENTS.md tables from results/dryrun/*.json and splice them
into EXPERIMENTS.md at the <!-- ... --> markers.

  PYTHONPATH=src python tools/make_experiments.py
"""
import glob
import json
import os
import re

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS = os.path.join(ROOT, "results", "dryrun")


def load(mesh_tag, variants=False):
    out = {}
    for f in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        base = os.path.basename(f)[:-5]
        if "FAILED" in base or f"_{mesh_tag}" not in base:
            continue
        is_var = "_v_" in base or "-dense" in base or "-bf16" in base
        if is_var != variants:
            continue
        out[base] = json.load(open(f))
    return out


def fe(x):
    return f"{x:.2e}" if x is not None else "-"


def dryrun_table(rs):
    lines = ["| cell | chips | mb | compile s | FLOPs/dev | HBM B/dev | "
             "wire B/dev | args GB | temp GB | collectives/step |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for tag in sorted(rs):
        r = rs[tag]
        mem = r.get("memory", {})
        args_gb = (mem.get("argument_bytes") or 0) / 1e9
        temp_gb = (mem.get("temp_bytes") or 0) / 1e9
        cc = r.get("coll_counts", {})
        short = {"all-gather": "ag", "all-reduce": "ar",
                 "reduce-scatter": "rs", "all-to-all": "a2a",
                 "collective-permute": "cp", "all-gather-start": "ag",
                 "all-reduce-start": "ar", "collective-permute-start": "cp"}
        agg = {}
        for k, v in cc.items():
            agg[short.get(k, k)] = agg.get(short.get(k, k), 0) + v
        cstr = " ".join(f"{k}:{int(v)}" for k, v in sorted(agg.items()))
        lines.append(
            f"| {r['arch']} {r['shape']} | {r['chips']} "
            f"| {r.get('microbatches', '-')} "
            f"| {r['t_compile_s']} | {fe(r['flops_per_dev'])} "
            f"| {fe(r['bytes_per_dev'])} "
            f"| {fe(r.get('wire_bytes_per_dev'))} "
            f"| {args_gb:.2f} | {temp_gb:.1f} | {cstr} |")
    return "\n".join(lines)


def roofline_table(rs):
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | useful | one-line fix for the dominant term |",
             "|---|---|---|---|---|---|---|---|"]
    fixes = {
        "collective": "cut resharding/all-gather volume: arch-aware rules "
        "(attn_dp), EP bins, bf16 reduce, fewer layout transitions",
        "memory": "cut bytes/step: fuse op chains (Pallas), bf16 bulk "
        "tensors, lighter remat (dots policy), fewer per-layer passes",
        "compute": "near knee: raise arithmetic intensity or accept",
    }
    for tag in sorted(rs):
        r = rs[tag]
        t = r["roofline"]
        ur = r.get("useful_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fe(t['compute_s'])} "
            f"| {fe(t['memory_s'])} | {fe(t['collective_s'])} "
            f"| **{t['dominant']}** "
            f"| {f'{ur:.3f}' if ur else '-'} "
            f"| {fixes[t['dominant']]} |")
    return "\n".join(lines)


def splice(markers_to_text):
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    txt = open(path).read()
    for marker, content in markers_to_text.items():
        pat = re.compile(
            rf"<!-- {marker} -->.*?(?=\n## |\n---|\Z)", re.S)
        block = f"<!-- {marker} -->\n\n{content}\n"
        if pat.search(txt):
            txt = pat.sub(block, txt)
        else:
            txt = txt.replace(f"<!-- {marker} -->", block)
    open(path, "w").write(txt)


if __name__ == "__main__":
    single = load("pod16x16")
    multi = load("pod2x16x16")
    dr = ("### Single pod (16x16 = 256 chips)\n\n" + dryrun_table(single)
          + "\n\n### Multi-pod (2x16x16 = 512 chips)\n\n"
          + dryrun_table(multi))
    rt = roofline_table(single)
    splice({"DRYRUN_TABLES": dr, "ROOFLINE_TABLE": rt})
    print("tables spliced:", len(single), "single-pod cells,",
          len(multi), "multi-pod cells")
