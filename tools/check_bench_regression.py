"""Fail CI when a kernel microbenchmark regresses against the baseline.

  python tools/check_bench_regression.py --fresh /tmp/BENCH_fresh.json \
      [--baseline BENCH_kernels.json] [--threshold 2.0] [--min-wall 0.005]

Rows are matched on ``(kernel, backend, monoid, scale)``.  A row fails
when its fresh wall time exceeds ``threshold``× the baseline's *after
machine calibration*.  Three guards keep the check meaningful when the
baseline was committed from a different machine than the CI runner:

  * machine calibration: the 25th-percentile fresh/baseline ratio over
    the matched rows above the noise floor estimates how much slower the
    runner is than the baseline host, and baselines are scaled by it
    before the threshold test.  A low percentile (not the median) so
    that only a near-uniform shift — machine speed — calibrates away,
    while a subset of regressed kernels cannot outvote the healthy ones.
    The factor is clamped to [1, ``--max-calibration``]: it can forgive
    a slower runner, never a uniformly *regressed* tree (a global
    slowdown beyond the clamp still fails), and never tightens the
    bound on a faster runner;
  * rows whose fresh time is under ``--min-wall`` seconds are skipped —
    micro-times in the hundreds of microseconds are dispatch jitter, not
    kernel work;
  * the calibrated baseline is floored at ``--min-wall`` before the
    ratio, so a lucky sub-millisecond baseline cannot flag an equally
    trivial fresh row.

Zero overlapping rows is itself a failure: it means the bench schema or
the baseline rotted and the guard is no longer guarding anything.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]


def row_key(r: dict) -> tuple:
    return (r["kernel"], r["backend"], r.get("monoid", "add"),
            r.get("scale"))


def check(fresh: dict, baseline: dict, threshold: float, min_wall: float,
          max_calibration: float = 3.0) -> int:
    base = {row_key(r): r["wall_s"] for r in baseline["results"]}
    matched = [(row_key(r), r["wall_s"], base[row_key(r)])
               for r in fresh["results"] if row_key(r) in base]
    if not matched:
        print("error: no rows of the fresh run match the baseline — "
              "regenerate the committed BENCH_kernels.json")
        return 2
    # calibrate on rows big enough to time reliably; sub-floor rows are
    # dispatch jitter and would let a lucky vote mask real regressions.
    # Take a LOW percentile, not the median: machine speed shifts every
    # row, a regression shifts only some — a median would forgive up to
    # half the rows regressing threshold x clamp at once
    votes = sorted(fw / bw for _, fw, bw in matched
                   if bw > 0 and fw >= min_wall) \
        or sorted(fw / bw for _, fw, bw in matched if bw > 0)
    factor = min(max(votes[len(votes) // 4], 1.0), max_calibration)
    print(f"machine calibration factor: {factor:.2f}x "
          f"(clamped to [1, {max_calibration}])")
    regressed = 0
    for key, fw, bw in matched:
        if fw < min_wall:
            print(f"  skip {key}: fresh {fw*1e3:.3f}ms < "
                  f"{min_wall*1e3:.1f}ms floor")
            continue
        ratio = fw / max(bw * factor, min_wall)
        tag = "REGRESSED" if ratio > threshold else "ok"
        print(f"  {tag} {key}: {bw*1e3:.3f}ms -> {fw*1e3:.3f}ms "
              f"({ratio:.2f}x calibrated)")
        if ratio > threshold:
            regressed += 1
    if regressed:
        print(f"{regressed} kernel timing(s) regressed more than "
              f"{threshold}x")
        return 1
    print(f"all {len(matched)} matched rows within {threshold}x of the "
          "calibrated baseline")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True,
                    help="freshly generated BENCH_kernels.json")
    ap.add_argument("--baseline",
                    default=str(REPO_ROOT / "BENCH_kernels.json"),
                    help="committed baseline (default: repo root)")
    ap.add_argument("--threshold", type=float, default=2.0)
    ap.add_argument("--min-wall", type=float, default=0.005,
                    help="seconds below which rows are noise, not signal")
    ap.add_argument("--max-calibration", type=float, default=3.0,
                    help="max machine-speed difference forgiven")
    args = ap.parse_args()
    fresh = json.loads(Path(args.fresh).read_text())
    baseline = json.loads(Path(args.baseline).read_text())
    sys.exit(check(fresh, baseline, args.threshold, args.min_wall,
                   args.max_calibration))


if __name__ == "__main__":
    main()
