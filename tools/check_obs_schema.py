"""Validate an exported telemetry JSONL file against the checked-in
event schema.

  python tools/check_obs_schema.py EVENTS.jsonl [more.jsonl ...] \
      [--schema tools/obs_schema.json] [--require engine_iter,serve_batch]

Deliberately repo-import-free: CI validates the uploaded artifact with
nothing but the stdlib and ``tools/obs_schema.json`` (the checked-in
serialization of ``repro.obs.schema.EVENT_SCHEMA``; a unit test asserts
the two never diverge).  The validation rules mirror
``repro.obs.schema.validate_event``:

  * every record needs a known ``"event"`` type and a numeric ``"ts"``;
  * every field the schema marks required must be present with the
    declared type (``float`` accepts ints; ``bool`` is rejected where an
    int/float is asked — bool is an int subclass in Python);
  * extra fields are always allowed (events are forward-extensible).

``--require`` additionally fails the run when the file contains no
record of a listed event type — the CI smoke uses it to prove the
workload actually exercised the engine and serving instrumentation, not
just produced a syntactically valid (possibly empty) file.

Exit status: 0 clean, 1 any violation (reported with line numbers).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]
DEFAULT_SCHEMA = REPO_ROOT / "tools" / "obs_schema.json"

TYPE_TAGS = {
    "str": (str,),
    "int": (int,),
    "float": (int, float),
    "bool": (bool,),
}


def validate_record(rec, schema):
    """Violation strings for one parsed record (empty when valid)."""
    errs = []
    ev = rec.get("event")
    if not isinstance(ev, str):
        return ["missing/invalid 'event' field"]
    spec = schema["events"].get(ev)
    if spec is None:
        return [f"unknown event type {ev!r}"]
    if not isinstance(rec.get("ts"), (int, float)) \
            or isinstance(rec.get("ts"), bool):
        errs.append(f"{ev}: missing/invalid 'ts'")
    for field, tag in spec["required"].items():
        if field not in rec:
            errs.append(f"{ev}: missing required field {field!r}")
            continue
        v = rec[field]
        if isinstance(v, bool) and tag in ("int", "float"):
            errs.append(f"{ev}: field {field!r} expected {tag}, got bool")
        elif not isinstance(v, TYPE_TAGS[tag]):
            errs.append(f"{ev}: field {field!r} expected {tag}, "
                        f"got {type(v).__name__}")
    return errs


def check_file(path: Path, schema, seen: dict) -> list:
    errs = []
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                errs.append(f"{path}:{lineno}: not JSON ({e})")
                continue
            if not isinstance(rec, dict):
                errs.append(f"{path}:{lineno}: record is not an object")
                continue
            for v in validate_record(rec, schema):
                errs.append(f"{path}:{lineno}: {v}")
            ev = rec.get("event")
            if isinstance(ev, str):
                seen[ev] = seen.get(ev, 0) + 1
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="+", help="JSONL event files")
    ap.add_argument("--schema", default=str(DEFAULT_SCHEMA))
    ap.add_argument("--require", default=None,
                    help="comma-separated event types that must appear "
                         "at least once across the input files")
    args = ap.parse_args(argv)

    schema = json.loads(Path(args.schema).read_text())
    seen: dict = {}
    errs = []
    total = 0
    for fname in args.files:
        p = Path(fname)
        if not p.exists():
            errs.append(f"{p}: no such file")
            continue
        before = sum(seen.values())
        errs.extend(check_file(p, schema, seen))
        total += sum(seen.values()) - before
    if args.require:
        for ev in args.require.split(","):
            ev = ev.strip()
            if ev and not seen.get(ev):
                errs.append(f"required event type {ev!r} never appeared")
    if errs:
        for e in errs:
            print(e, file=sys.stderr)
        print(f"FAIL: {len(errs)} violation(s) over {total} record(s)",
              file=sys.stderr)
        return 1
    counts = ", ".join(f"{k}={v}" for k, v in sorted(seen.items()))
    print(f"OK: {total} record(s) valid ({counts})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
