"""Serving: KV/state caches, prefill + single-token decode, and a slot-based
continuous-batching server loop.

Cache layouts (layer-stacked so decode scans layers exactly like training):
  attention archs: k/v [L, B, W, KV, dh]  (W = window for SWA else max_len),
                   pos [B, W] absolute positions (-1 empty), len [B]
  ssm archs:       h [L, B, H, N, P] f32, conv [L, B, K-1, di+2N], len [B]
  hybrid:          ssm fields + shared-attn caches sk/sv
                   [n_inv, B, W, KV, dh]

Graph-analytics serving (:class:`GraphQueryServer`) applies the same
continuous-batching idea to PPM queries over one resident layout:

  * **Batched multi-source execution** — queued BFS / SSSP /
    SSSP-with-parents queries that differ only in their source vertex are
    drained into one per-app batch and answered by a single fused
    :meth:`repro.core.engine.Engine.run_batched` invocation (the compiled
    DC iteration vmapped over a leading query axis), so every
    scatter/gather/fold kernel launch is amortized across the batch.
  * **Power-of-two padding** — batches are padded up to the next power of
    two (by repeating the first source; padded lanes are discarded), so
    the engine's per-batch-size jit cache holds at most log2(max_batch)
    compiled steps instead of one per distinct queue depth.
  * **Result memoization + semantic caching** — every cache entry lives
    in one pluggable :class:`repro.serve.cache.CacheBackend` (in-memory
    LRU or disk-backed; ``ServeConfig.cache_backend``) under the
    documented key space of :mod:`repro.serve.cache`: exact-match query
    results under ``res|…`` and converged per-partition *landmark* state
    under ``sem|…``.  A miss whose source is within reach of a cached
    landmark is answered by a landmark-seeded run — exactly correct on
    symmetric graphs (see the seeding proof in
    :mod:`repro.serve.cache`), converging in fewer or equal iterations.
    An async :class:`repro.serve.cache.CacheWarmer` turns repeated
    sources into precomputed landmarks on idle scheduler ticks.

    **Invalidation rules** (scoped per layout tag + epoch semantics):

    - entries are keyed by the resident layout's *content* tag; the
      server serves exactly one resident layout per epoch and never
      mutates it in place;
    - :meth:`GraphQueryServer.clear_cache` is the ONLY wholesale
      invalidation (``backend.clear()``);
    - :meth:`GraphQueryServer.swap_layout` starts a new epoch: in-flight
      queries drain on the old layout first (the old binding is the read
      buffer until the new one binds), then the epoch counter bumps and
      the shared engines / warmer statistics / old-tag metric series
      reset.  A *plain* swap evicts **nothing** — entries under other
      tags are simply invisible until their layout returns (A -> B -> A
      revalidates A's entries for free);
    - a *delta* swap (``swap_layout(new, delta=...)`` with the
      :class:`repro.graph.delta.DeltaBuffer` that produced ``new``)
      additionally garbage-collects what the delta invalidated, scoped
      by per-partition content tags
      (:func:`repro.serve.cache.partition_tags`): the old tag's ``res|``
      entries are evicted via :meth:`CacheBackend.evict_prefix` (a
      global answer is stale under any edge edit), and old ``sem|``
      entries are evicted only when their stored partitions intersect a
      changed partition tag — clean-partition entries of an
      insertion-only delta are *migrated* (re-keyed) to the new tag,
      where they remain sound seeds: inserting edges can only lower
      min-monoid distances, so the old converged state stays a pointwise
      upper bound of the new fixpoint and seeded relaxation corrects it
      exactly.  Deltas with deletions evict every old-tag ``sem|`` entry
      (deletions can *raise* distances; an under-bound seed would be
      believed, not corrected);
    - semantic entries are additionally gated at *read* time: seeding is
      skipped entirely on asymmetric graphs (auto-detected per layout:
      structure for BFS, structure + weights for SSSP) and under
      distributed serving, so a stale-looking entry can demote a query
      to a cold run but never corrupt it.

    Cached results are returned by reference (memory backend) and must
    be treated as read-only.
  * **Distributed batching** — constructed with ``sharded=`` (a
    :func:`repro.graph.shard.shard_layout` of the resident layout) and
    ``mesh=``, the shared engines become
    :class:`repro.dist.engine.DistEngine` instances and each drained
    batch advances across the device mesh through
    :meth:`~repro.dist.engine.DistEngine.run_batched`: the DC bin
    exchange carries ``[B, D, S]`` in one all_to_all per payload, so
    every collective launch is amortized over the batch.  The sharded
    global vertex space equals the single-device padded space
    (``D*nv == n_pad``), so batching, pow2 padding, and the LRU cache
    work unchanged — the cache key stays layout identity, and the same
    invalidation rule applies.
  * **Wire compression** (dist only) — the B× blowup of the dense bin
    exchange is attacked on the wire, not in compute.  Validity flags
    always cross as a packed frontier bitmap (``wire_bitmap``, 8× smaller
    than bool lanes, bit-exact).  ``wire_bf16=True`` additionally halves
    the value payload for f32 monoids; that rounds SSSP distances to bf16
    on the wire (approximate — but identically for batched and
    sequential runs under one engine, so parity holds), while integer id
    monoids (BFS/CC) and the packed uint64 SSSP-parents monoid skip the
    cast and stay exact.
"""
from __future__ import annotations

import collections
import dataclasses
import time
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from . import ServeConfig
from . import cache as cache_lib
from ..models import moe as moe_lib
from ..models import ssm as ssm_lib
from ..models.config import ModelConfig
from ..models.layers import (decode_attention, mlp_fwd, rms_norm, rope)
from ..models.transformer import (_shared_block, backbone, embed_tokens,
                                  lm_logits_last)


# ----------------------------------------------------------------------
# cache init
# ----------------------------------------------------------------------

def cache_width(cfg: ModelConfig, max_len: int) -> int:
    return min(max_len, cfg.swa_window) if cfg.swa_window else max_len


def init_cache(cfg: ModelConfig, B: int, max_len: int, dtype=jnp.bfloat16):
    L = cfg.n_layers
    c = {"len": jnp.zeros((B,), jnp.int32)}
    if cfg.family in ("ssm", "hybrid"):
        H, N, P = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
        ch = cfg.d_inner + 2 * cfg.ssm_state
        c["h"] = jnp.zeros((L, B, H, N, P), jnp.float32)
        c["conv"] = jnp.zeros((L, B, cfg.ssm_conv - 1, ch), dtype)
        if cfg.family == "hybrid" and cfg.attn_every:
            W = cache_width(cfg, max_len)
            n_inv = (L + cfg.attn_every - 1) // cfg.attn_every
            c["sk"] = jnp.zeros((n_inv, B, W, cfg.n_kv, cfg.d_head), dtype)
            c["sv"] = jnp.zeros((n_inv, B, W, cfg.n_kv, cfg.d_head), dtype)
            c["pos"] = jnp.full((B, W), -1, jnp.int32)
    else:
        W = cache_width(cfg, max_len)
        c["k"] = jnp.zeros((L, B, W, cfg.n_kv, cfg.d_head), dtype)
        c["v"] = jnp.zeros((L, B, W, cfg.n_kv, cfg.d_head), dtype)
        c["pos"] = jnp.full((B, W), -1, jnp.int32)
    return c


# ----------------------------------------------------------------------
# prefill
# ----------------------------------------------------------------------

def prefill(params, cfg: ModelConfig, batch, cache, *, dtype=jnp.bfloat16):
    """Fill the cache from a full prompt.  batch: tokens [B,S] or embeds.
    Assumes all B rows share length S (per-slot prefill in the server)."""
    if cfg.frontend is not None and "embeds" in batch:
        from ..models.transformer import embed_frontend
        h = embed_frontend(params, cfg, batch["embeds"], dtype)
    else:
        h = embed_tokens(params, cfg, batch["tokens"], dtype)
    B, S = h.shape[:2]
    positions = jnp.arange(S, dtype=jnp.int32)
    x, states = backbone(params, cfg, h, positions, dtype=dtype,
                         remat=False, collect_cache=True)
    logits = lm_logits_last(params, cfg, x, dtype)
    if cfg.family in ("ssm", "hybrid"):
        cache = dict(cache, h=states["ssm_h"],
                     conv=states["ssm_conv"].astype(cache["conv"].dtype),
                     len=jnp.full((B,), S, jnp.int32))
        if "sk" in cache:
            W = cache["sk"].shape[2]
            slots = positions % W
            sk = cache["sk"].at[:, :, slots].set(
                states["shared_kv"][0].astype(cache["sk"].dtype)
                .transpose(0, 1, 2, 3, 4))
            sv = cache["sv"].at[:, :, slots].set(
                states["shared_kv"][1].astype(cache["sv"].dtype))
            pos = cache["pos"].at[:, slots].set(positions[None, :])
            cache = dict(cache, sk=sk, sv=sv, pos=pos)
    else:
        W = cache["k"].shape[2]
        slots = positions % W
        k = cache["k"].at[:, :, slots].set(
            states["k"].astype(cache["k"].dtype))
        v = cache["v"].at[:, :, slots].set(
            states["v"].astype(cache["v"].dtype))
        pos = cache["pos"].at[:, slots].set(positions[None, :])
        cache = dict(cache, k=k, v=v, pos=pos,
                     len=jnp.full((B,), S, jnp.int32))
    return logits[:, 0], cache


# ----------------------------------------------------------------------
# decode
# ----------------------------------------------------------------------

def _dense_decode_block(pl, cfg, x, kc, vc, pos_c, q_pos, dtype):
    B = x.shape[0]
    H, KV, dh = cfg.n_heads, cfg.n_kv, cfg.d_head
    h = rms_norm(x, pl["ln1"], cfg.norm_eps)
    q = (h @ pl["attn"]["wq"].astype(dtype)).reshape(B, 1, H, dh)
    k = (h @ pl["attn"]["wk"].astype(dtype)).reshape(B, 1, KV, dh)
    v = (h @ pl["attn"]["wv"].astype(dtype)).reshape(B, 1, KV, dh)
    if cfg.qkv_bias:
        q = q + pl["attn"]["bq"].astype(dtype).reshape(H, dh)
        k = k + pl["attn"]["bk"].astype(dtype).reshape(KV, dh)
        v = v + pl["attn"]["bv"].astype(dtype).reshape(KV, dh)
    q = rope(q, q_pos[:, None], cfg.rope_theta)
    k = rope(k, q_pos[:, None], cfg.rope_theta)
    W = kc.shape[1]
    slot = (q_pos % W).astype(jnp.int32)
    bidx = jnp.arange(B)
    kc = kc.at[bidx, slot].set(k[:, 0].astype(kc.dtype))
    vc = vc.at[bidx, slot].set(v[:, 0].astype(vc.dtype))
    a = decode_attention(q, kc, vc, q_position=q_pos, kv_positions=pos_c,
                         kv_valid=pos_c >= 0, window=cfg.swa_window)
    x = x + a.reshape(B, 1, H * dh) @ pl["attn"]["wo"].astype(dtype)
    h = rms_norm(x, pl["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        x = x + moe_lib.moe_fwd(pl["moe"], cfg, h, dtype=dtype)
    else:
        x = x + mlp_fwd(pl["mlp"], h, dtype)
    return x, kc, vc


def decode_step(params, cfg: ModelConfig, tokens, cache, *,
                dtype=jnp.bfloat16):
    """One token for every active slot.  tokens: [B] int32."""
    B = tokens.shape[0]
    x = embed_tokens(params, cfg, tokens[:, None], dtype)
    q_pos = cache["len"]
    L = cfg.n_layers

    if cfg.family in ("ssm", "hybrid"):
        ae = cfg.attn_every
        hybrid = "sk" in cache
        if hybrid:
            W = cache["sk"].shape[2]
            slot = (q_pos % W).astype(jnp.int32)
            new_pos = cache["pos"].at[jnp.arange(B), slot].set(q_pos)
        x0 = x

        def body(carry, inp):
            x, sk, sv = carry
            pl, hst, conv, i = inp
            hh = rms_norm(x, pl["ln"], cfg.norm_eps)
            out, h2, conv2 = ssm_lib.ssm_block_decode(
                pl["ssm"], cfg, hh, hst, conv, dtype=dtype)
            x = x + out
            if hybrid:
                inv = i // ae

                def with_attn(opd):
                    x, sk, sv = opd
                    kc = sk[inv]
                    vc = sv[inv]
                    x2, (kc2, vc2) = _shared_block(
                        params["shared"], cfg, x, x0, None, dtype,
                        decode=True,
                        cache_ctx=(kc, vc, new_pos, q_pos))
                    sk = jax.lax.dynamic_update_index_in_dim(sk, kc2, inv, 0)
                    sv = jax.lax.dynamic_update_index_in_dim(sv, vc2, inv, 0)
                    return x2, sk, sv

                x, sk, sv = jax.lax.cond(
                    i % ae == ae - 1, with_attn, lambda o: o, (x, sk, sv))
            return (x, sk, sv), (h2, conv2)

        sk0 = cache.get("sk")
        sv0 = cache.get("sv")
        (x, sk, sv), (h_new, conv_new) = jax.lax.scan(
            body, (x, sk0, sv0),
            (params["layers"], cache["h"], cache["conv"],
             jnp.arange(L, dtype=jnp.int32)))
        cache = dict(cache, h=h_new, conv=conv_new,
                     len=cache["len"] + 1)
        if hybrid:
            cache = dict(cache, sk=sk, sv=sv, pos=new_pos)
    else:
        W = cache["k"].shape[2]
        slot = (q_pos % W).astype(jnp.int32)
        new_pos = cache["pos"].at[jnp.arange(B), slot].set(q_pos)

        def body(x, inp):
            pl, kc, vc = inp
            x, kc, vc = _dense_decode_block(pl, cfg, x, kc, vc, new_pos,
                                            q_pos, dtype)
            return x, (kc, vc)

        x, (k_new, v_new) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"]))
        cache = dict(cache, k=k_new, v=v_new, pos=new_pos,
                     len=cache["len"] + 1)
    logits = lm_logits_last(params, cfg, x, dtype)
    return logits[:, 0], cache


# ----------------------------------------------------------------------
# slot-based batched server
# ----------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    #: number of DECODE steps; ``out`` ends up ``max_new + 1`` tokens long
    #: (the prefill-produced token plus one per decode step)
    max_new: int = 32
    out: Optional[list] = None


class Server:
    """Continuous batching over B fixed slots (greedy decoding)."""

    def __init__(self, params, cfg: ModelConfig, n_slots: int = 4,
                 max_len: int = 512, dtype=jnp.bfloat16):
        self.params, self.cfg = params, cfg
        self.B, self.max_len = n_slots, max_len
        self.dtype = dtype
        self.cache = init_cache(cfg, n_slots, max_len, dtype)
        self.free = list(range(n_slots))
        self.active = {}                       # slot -> Request
        self.queue = collections.deque()
        self.done = []
        self._decode = jax.jit(
            lambda p, t, c: decode_step(p, cfg, t, c, dtype=dtype))
        self._next_tok = np.zeros(n_slots, np.int32)

    def submit(self, req: Request):
        req.out = []
        self.queue.append(req)

    def _prefill_into_slot(self, slot: int, req: Request):
        # single-row prefill, then splice the row into the batched cache
        row_cache = init_cache(self.cfg, 1, self.max_len, self.dtype)
        toks = jnp.asarray(req.prompt[None, :])
        logits, row_cache = prefill(self.params, self.cfg,
                                    {"tokens": toks}, row_cache,
                                    dtype=self.dtype)
        tok = int(jnp.argmax(logits[0]))
        req.out.append(tok)
        self._next_tok[slot] = tok

        # layer-stacked entries carry batch on axis 1; per-slot on axis 0
        LAYER_STACKED = ("k", "v", "h", "conv", "sk", "sv")

        def splice_entry(k):
            if k in LAYER_STACKED:
                return self.cache[k].at[:, slot].set(row_cache[k][:, 0])
            return self.cache[k].at[slot].set(row_cache[k][0])

        self.cache = {k: splice_entry(k) for k in self.cache}
        self.active[slot] = req

    def step(self):
        """One scheduler tick: admit new requests, then decode one token."""
        admitted = 0
        while self.free and self.queue:
            slot = self.free.pop()
            self._prefill_into_slot(slot, self.queue.popleft())
            admitted += 1
        if obs.enabled():
            obs.set_gauge("serve.lm_queue_depth", len(self.queue))
            obs.set_gauge("serve.lm_active_slots", len(self.active))
            if admitted:
                obs.inc("serve.lm_admitted", admitted)
        # max_new counts DECODE steps: the prefill-produced token is not
        # one of them, so a max_new<=0 request finishes right after
        # prefill and the finish test below discounts that first token
        # (counting it made every request decode one step short)
        for slot, req in list(self.active.items()):
            if len(req.out) - 1 >= req.max_new:
                self.done.append(self.active.pop(slot))
                self.free.append(slot)
        if not self.active:
            return False
        toks = jnp.asarray(self._next_tok)
        t0 = time.perf_counter()
        logits, self.cache = self._decode(self.params, toks, self.cache)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        if obs.enabled():
            # argmax already synced; one token per *active* slot this tick
            obs.inc("serve.lm_tokens", len(self.active))
            obs.observe("serve.lm_decode_wall_s",
                        time.perf_counter() - t0)
        finished = []
        for slot, req in list(self.active.items()):
            req.out.append(int(nxt[slot]))
            self._next_tok[slot] = int(nxt[slot])
            if len(req.out) - 1 >= req.max_new:
                finished.append(slot)
        for slot in finished:
            self.done.append(self.active.pop(slot))
            self.free.append(slot)
        return True

    def run(self, max_ticks: int = 10_000):
        t = 0
        while (self.queue or self.active) and t < max_ticks:
            self.step()
            t += 1
        return self.done


# ----------------------------------------------------------------------
# graph-analytics serving (PPM queries over one resident layout)
# ----------------------------------------------------------------------

@dataclasses.dataclass
class GraphQuery:
    qid: int
    app: str                          # bfs | sssp | cc | pagerank | nibble
    params: dict = dataclasses.field(default_factory=dict)
    result: Optional[dict] = None


class GraphQueryServer:
    """Serve repeated graph-analytics queries over one resident layout.

    The serving analogue of the paper's §5 repeated-Nibble argument: the
    O(E) layout build is paid once, and parameter-free vertex programs
    (BFS / SSSP / CC) share one compiled :class:`repro.core.engine.Engine`
    across queries, so a second query from a different source vertex pays
    only the iteration loop.  Every kernel call dispatches through
    :mod:`repro.backend` — the serving tier inherits the backend choice
    (and any autotuned tile geometry) from the same registry as the batch
    engines.

    :meth:`step` is a real scheduler tick: it drains every queued query
    that is batchable with the head of the queue (same app, same
    non-source params, every param within the ``*_multi`` signature —
    engine overrides and single-path-only kwargs opt out) into one
    per-app batch, pads
    the distinct sources to the next power of two (bounding the jit
    cache), and answers the whole batch with a single fused
    :meth:`~repro.core.engine.Engine.run_batched` invocation.  Repeated
    ``(app, params)`` queries are memoized as exact-match entries in the
    cache backend; BFS/SSSP misses near a cached landmark run
    landmark-seeded (see the module docstring for the caching design and
    the invalidation rules).  After every tick the async warmer gets a
    small fixed budget (``ServeConfig.warm_budget`` jobs) — bounded so a
    drain taxes one tick by at most that many cold runs, but never
    skipped, so sustained traffic (exactly when hot sources exist)
    cannot starve landmark precomputation.  Queries
    overriding ``mode`` / ``backend`` / ``bw_ratio`` run on a dedicated
    engine and never touch the shared engine cache.
    """

    #: apps whose queries differ only in ``source`` and can share a batch
    BATCHED_APPS = ("bfs", "sssp", "sssp_parents")
    #: the full param set the ``*_multi`` entry points accept; a query
    #: carrying anything else (engine overrides, single-path-only kwargs
    #: like ``use_pallas``) must take the single-query path
    BATCH_PARAMS = frozenset({"source", "max_iters"})
    #: engine-construction params: a query overriding any of these cannot
    #: share the server engine (all three are baked in at construction)
    ENGINE_KEYS = frozenset({"mode", "backend", "bw_ratio"})
    #: apps the semantic cache can seed: the landmark-proximity distance
    #: field, the converged state fields captured per landmark, and each
    #: field's fill value on untouched partitions.  ``sssp_parents`` is
    #: deliberately absent: its packed payload seeds need a subtler
    #: upper-bound argument, so it gets exact-match caching only.
    SEEDED_FIELDS = {
        "bfs": ("level", ("level", "parent"),
                {"level": -1.0, "parent": -1.0}),
        "sssp": ("dist", ("dist",), {"dist": float("inf")}),
    }

    def __init__(self, layout, config: Optional[ServeConfig] = None,
                 **legacy):
        if legacy:
            warnings.warn(
                "passing GraphQueryServer options as keyword arguments "
                "is deprecated; pass a repro.serve.ServeConfig",
                DeprecationWarning, stacklevel=2)
            known = {f.name for f in dataclasses.fields(ServeConfig)}
            unknown = set(legacy) - known
            if unknown:
                raise TypeError("unknown GraphQueryServer option(s): "
                                f"{sorted(unknown)}")
            config = dataclasses.replace(config or ServeConfig(), **legacy)
        config = config or ServeConfig()
        if (config.sharded is None) != (config.mesh is None):
            raise ValueError("distributed serving needs BOTH sharded and "
                             "mesh (or neither)")
        self.config = config
        self.layout = layout
        # legacy attribute surface (mirrors of the config)
        self.backend = config.backend
        self.mode = config.mode
        self.max_batch = config.max_batch
        self.cache_size = config.cache_size
        #: when set (with ``mesh``), shared engines are
        #: :class:`repro.dist.engine.DistEngine` instances over the
        #: sharded layout and batches fan out across the device mesh
        self.sharded = config.sharded
        self.mesh = config.mesh
        self.wire_bf16 = config.wire_bf16
        self.wire_bitmap = config.wire_bitmap
        self._engines = {}            # app name -> shared (Dist)Engine
        self.queue = collections.deque()
        self.done = []
        #: the pluggable CacheBackend every cache entry lives in (exact
        #: results AND semantic landmark state — one shared namespace)
        self.cache = cache_lib.make_backend(config.cache_backend,
                                            config.cache_size)
        self.cache_hits = 0
        self.cache_misses = 0
        self.semantic_hits = 0        # lanes answered landmark-seeded
        self.semantic_misses = 0      # seedable lanes with no landmark
        # metric series are labeled by layout identity: hit rates and
        # latencies must never aggregate across incompatible layouts
        # (cache keys are layout-identity too — same invalidation rule)
        self._layout_tag = cache_lib.layout_tag(layout)
        #: monotone swap counter; queries admitted before a swap drain on
        #: the old layout (epoch N), queries after run on the new (N+1)
        self.epoch = 0
        self._bind_layout()

    def _bind_layout(self):
        """(Re)build the layout-scoped cache clients: the semantic view,
        the warmer, and the lazily-computed symmetry flags."""
        lay, cfg = self.layout, self.config
        self.semantic = (cache_lib.SemanticCache(
            self.cache, self._layout_tag, lay.k, lay.q, lay.n_pad)
            if cfg.semantic else None)
        self.warmer = (cache_lib.CacheWarmer(
            self.semantic, threshold=cfg.warm_threshold,
            budget=cfg.warm_budget) if self.semantic is not None else None)
        self._sym = {}                # weights-flag -> bool (lazy)

    def _symmetric(self, need_weights: bool) -> bool:
        """Seeding precondition, computed once per layout (per strength:
        BFS needs structural symmetry, SSSP structure + weights)."""
        flag = self._sym.get(need_weights)
        if flag is None:
            flag = cache_lib.layout_is_symmetric(self.layout,
                                                 weights=need_weights)
            self._sym[need_weights] = flag
        return flag

    def _seedable(self, app: str) -> bool:
        return (self.semantic is not None and app in self.SEEDED_FIELDS
                and self.sharded is None
                and self._symmetric(need_weights=(app == "sssp")))

    # ---- shared engines ------------------------------------------------
    def _shared_engine(self, app: str, make_program):
        eng = self._engines.get(app)
        if eng is None:
            # engine construction never traces the program (only the app
            # fns do, inside their own enable_x64 for sssp_parents), so
            # no x64 context is needed here
            if self.sharded is not None:
                from ..dist.engine import DistEngine
                # D*nv == layout.n_pad: the sharded global vertex space
                # IS the single-device padded space, so the same *_multi
                # state construction drives the mesh unchanged
                eng = DistEngine(self.sharded, make_program(), self.mesh,
                                 mode=self.mode, backend=self.backend,
                                 wire_bf16=self.wire_bf16,
                                 wire_bitmap=self.wire_bitmap)
            else:
                from ..core.engine import Engine
                eng = Engine(self.layout, make_program(), mode=self.mode,
                             backend=self.backend)
            self._engines[app] = eng
        return eng

    # ---- cache clients (exact results + semantic state) ----------------
    def _result_key(self, q: GraphQuery) -> Optional[str]:
        """The exact-match entry key (``res|…`` in the documented key
        space of :mod:`repro.serve.cache`) or None when a param value
        defies canonicalization (such a query simply isn't memoized)."""
        return cache_lib.result_key(self._layout_tag, q.app, q.params)

    def _result_get(self, q: GraphQuery):
        key = self._result_key(q)
        return self.cache.get(key) if key is not None else None

    def _note_cache(self, hit: bool, app: str):
        if hit:
            self.cache_hits += 1
        else:
            self.cache_misses += 1
        if obs.enabled():
            obs.inc("serve.cache_hits" if hit else "serve.cache_misses",
                    layout=self._layout_tag, app=app)

    def _reset_layout_metrics(self):
        """Drop this layout's metric series along with the hit/miss ints:
        a cleared (or swapped-out) cache must not keep feeding hit-rate
        gauges computed against a different cache population."""
        self.cache_hits = 0
        self.cache_misses = 0
        self.semantic_hits = 0
        self.semantic_misses = 0
        if obs.enabled():
            reg = obs.registry()
            for name in ("serve.cache_hits", "serve.cache_misses",
                         "serve.semantic_hits", "serve.semantic_misses",
                         "serve.seed_iters_saved", "serve.source_freq",
                         "serve.warmed_landmarks",
                         "serve.query_wall_s", "serve.batch_wall_s"):
                reg.reset_metric(name, layout=self._layout_tag)

    def clear_cache(self):
        """Invalidate everything: one :meth:`CacheBackend.clear` drops
        exact results AND semantic landmark state — the only wholesale
        invalidation in the serve tier (layout swaps are scoped, see
        :meth:`swap_layout`) — and the warmer forgets its statistics."""
        self.cache.clear()
        if self.warmer is not None:
            self.warmer.reset()
        self._reset_layout_metrics()
        if obs.enabled():
            obs.event("cache_clear", layout=self._layout_tag)

    def _scoped_invalidate(self, old_layout, old_tag, new_layout, new_tag,
                           delta):
        """Delta-swap garbage collection, scoped by per-partition content
        tags.  Returns ``(evicted, migrated, changed_parts)``.

        The old tag's ``res|`` prefix is always evicted (an exact global
        answer is stale under any edge edit).  A ``sem|`` landmark entry
        is judged by the partitions it stores: if the delta is
        insertion-only and none of them changed tag, the entry is
        *migrated* — re-keyed under the new tag, where its state is still
        a pointwise upper bound of every new fixpoint (insertions only
        lower min-monoid distances), i.e. exactly what a seed needs to
        be.  Everything else under ``sem|<old>|`` is evicted."""
        old_ptags = cache_lib.partition_tags(old_layout)
        new_ptags = cache_lib.partition_tags(new_layout)
        changed = {p for p, (a, b) in enumerate(zip(old_ptags, new_ptags))
                   if a != b}
        evicted = cache_lib.evict_prefix(self.cache, f"res|{old_tag}|")
        migratable = delta.insertions_only
        sem_prefix = f"sem|{old_tag}|"
        migrated = 0
        for key in list(self.cache.keys()):
            if not isinstance(key, str) or not key.startswith(sem_prefix):
                continue
            entry = self.cache.get(key) if migratable else None
            if entry is not None:
                parts = set(np.asarray(entry.get("parts", ())).tolist())
                if not (parts & changed):
                    new_key = f"sem|{new_tag}|" + key[len(sem_prefix):]
                    self.cache.put(new_key, entry)
                    self.cache.evict(key)
                    migrated += 1
                    continue
            if self.cache.evict(key):
                evicted += 1
        return evicted, migrated, changed

    def swap_layout(self, layout, sharded=None, mesh=None, delta=None):
        """Re-point the server at a new resident layout (a new epoch).

        Double-buffered: queued queries admitted under the old epoch are
        drained on the old layout *first* (it stays the read buffer until
        the new one binds), then the epoch counter bumps, the shared
        engines are dropped, and the warmer statistics / old-tag metric
        series reset (hit ratios across incompatible layouts are
        meaningless).

        Invalidation is **scoped**, never wholesale (that is
        :meth:`clear_cache`'s job): with ``delta=None`` nothing is
        evicted — every entry is keyed by content tag, so entries of
        other layouts are merely invisible until their layout returns.
        With ``delta=`` the :class:`repro.graph.delta.DeltaBuffer` that
        produced ``layout`` (usually via
        :func:`repro.graph.delta.apply_delta`), the old tag's superseded
        entries are garbage-collected and clean-partition landmarks of an
        insertion-only delta are migrated to the new tag — see
        :meth:`_scoped_invalidate` for the soundness argument."""
        if (sharded is None) != (mesh is None):
            raise ValueError("distributed serving needs BOTH sharded and "
                             "mesh (or neither)")
        if delta is not None and (delta.k != layout.k
                                  or delta.q != layout.q
                                  or delta.n != layout.n):
            raise ValueError("delta partitioning does not match the new "
                             "layout (deltas never change k/q/n)")
        if self.queue:
            self.run()                 # drain epoch N on the old layout
        old_layout, old_tag = self.layout, self._layout_tag
        new_tag = cache_lib.layout_tag(layout)
        evicted = migrated = 0
        changed = set()
        if delta is not None:
            evicted, migrated, changed = self._scoped_invalidate(
                old_layout, old_tag, layout, new_tag, delta)
        self._engines = {}
        if self.warmer is not None:
            self.warmer.reset()
        self._reset_layout_metrics()
        self.layout = layout
        self.sharded = sharded
        self.mesh = mesh
        self.config = dataclasses.replace(self.config, sharded=sharded,
                                          mesh=mesh)
        self._layout_tag = new_tag
        self._bind_layout()
        self.epoch += 1
        if obs.enabled():
            obs.event("layout_swap", old=old_tag, new=new_tag)
            obs.event("epoch_swap", old=old_tag, new=new_tag,
                      epoch=self.epoch, delta=delta is not None,
                      changed_parts=len(changed), evicted=evicted,
                      migrated=migrated)

    # ---- batching ------------------------------------------------------
    def _batch_sig(self, q: GraphQuery):
        """Queries with equal signatures can ride one fused batch."""
        if q.app not in self.BATCHED_APPS or "source" not in q.params \
                or not (q.params.keys() <= self.BATCH_PARAMS):
            return None
        rest = {k: v for k, v in q.params.items() if k != "source"}
        try:
            return (q.app, tuple(sorted(rest.items())))
        except TypeError:
            return None

    # ---- landmark seeding ----------------------------------------------
    def _lookup_landmarks(self, app, extra, sources):
        """Best landmark per distinct source: ``(lm, entry, d_ls)`` or
        None.  Counts semantic hits/misses per lane."""
        dist_field = self.SEEDED_FIELDS[app][0]
        picks = []
        for s in sources:
            pick = self.semantic.best_landmark(
                app, extra, int(s), dist_field,
                max_distance=self.config.seed_max_distance)
            picks.append(pick)
            hit = pick is not None
            if hit:
                self.semantic_hits += 1
            else:
                self.semantic_misses += 1
            if obs.enabled():
                obs.inc("serve.semantic_hits" if hit
                        else "serve.semantic_misses",
                        app=app, layout=self._layout_tag)
        return picks

    def _sssp_seed_arrays(self, sources, picks):
        """Per-lane warm SSSP init: ``dist0[v] = d_L(v) + d_L(s)`` (a
        valid upper bound on symmetric graphs), ``dist0[s] = 0``, and a
        frontier covering every finite seed.  Unseeded lanes get the
        cold one-hot init."""
        n_pad = self.layout.n_pad
        dist0 = np.full((len(sources), n_pad), np.inf, np.float32)
        for i, (s, pick) in enumerate(zip(sources, picks)):
            if pick is not None:
                _, entry, d_ls = pick
                dist0[i] = self.semantic.expand(entry, "dist", np.inf)
                dist0[i] += np.float32(d_ls)
            dist0[i, s] = 0.0
        return dist0, np.isfinite(dist0)

    def _bfs_seed_arrays(self, sources, picks):
        """Per-lane warm BFS init: level upper bounds ``level_L + d_ls``
        with PARENT-UNKNOWN payloads (the sentinel loses every packed
        tie, so the seed stays a true upper bound in the lexicographic
        order even when the level bound is already tight)."""
        n_pad = self.layout.n_pad
        levels = np.full((len(sources), n_pad), -1, np.int64)
        parents = np.full((len(sources), n_pad), -1, np.int64)
        for i, (s, pick) in enumerate(zip(sources, picks)):
            if pick is not None:
                _, entry, d_ls = pick
                lv = self.semantic.expand(entry, "level", -1).astype(
                    np.int64)
                lv[lv >= 0] += int(d_ls)
                levels[i] = lv
            levels[i, s] = 0
            parents[i, s] = s
        return levels, parents, levels >= 0

    def _capture_landmarks(self, app, extra, sources, res, iters):
        """Opportunistically store each computed lane's converged state
        as a semantic landmark (results are exact whether the lane ran
        cold or seeded)."""
        dist_field, fields, fills = self.SEEDED_FIELDS[app]
        n, n_pad = self.layout.n, self.layout.n_pad
        for i, s in enumerate(sources):
            if self.semantic.get_state(app, extra, int(s)) is not None:
                continue
            fvecs = {}
            for name in fields:
                row = np.asarray(res[name][i])
                full = np.full(n_pad, fills[name], dtype=row.dtype)
                full[:n] = row
                fvecs[name] = full
            anchor = fvecs[dist_field]
            touched = (np.isfinite(anchor) if app == "sssp"
                       else anchor >= 0)
            self.semantic.put_state(app, extra, int(s), fvecs, touched,
                                    fills, iters)

    def _run_batch(self, batch):
        """Answer a same-signature batch with one fused run_batched call,
        landmark-seeding the lanes that are within reach of cached
        semantic state."""
        from ..apps.bfs import (bfs_multi, bfs_program, bfs_seeded_multi,
                                bfs_seeded_program)
        from ..apps.sssp import sssp_multi, sssp_program
        from ..apps.sssp_parents import (sssp_parents_multi,
                                         sssp_parents_program)
        multi = {"bfs": (bfs_multi, bfs_program),
                 "sssp": (sssp_multi, sssp_program),
                 "sssp_parents": (sssp_parents_multi, sssp_parents_program)}
        run = []                       # queries that actually need a lane
        for q in batch:
            cached = self._result_get(q)
            if cached is not None:
                self._note_cache(True, q.app)
                if obs.enabled():
                    obs.event("serve_query", app=q.app,
                              layout=self._layout_tag, cached=True,
                              wall_s=0.0)
                q.result = cached
                self.done.append(q)
            else:
                run.append(q)
        if not run:
            return
        app = run[0].app
        multi_fn, make_program = multi[app]
        # duplicate sources share a lane; pad to the next power of two by
        # repeating the first source so the per-batch-size jit cache stays
        # logarithmic in max_batch (padded lanes are discarded below)
        from ..core.engine import _next_pow2
        lane_of = {}
        for q in run:
            lane_of.setdefault(int(q.params["source"]), len(lane_of))
        distinct = list(lane_of)
        extra = {k: v for k, v in run[0].params.items() if k != "source"}
        picks = None
        if self._seedable(app):
            picks = self._lookup_landmarks(app, extra, distinct)
            if not any(p is not None for p in picks):
                picks = None           # nothing to seed: cold fast path
        pad = _next_pow2(len(distinct)) - len(distinct)
        sources = distinct + [distinct[0]] * pad
        t0 = time.perf_counter()
        if picks is not None:
            padded_picks = picks + [picks[0]] * pad
            if app == "sssp":
                dist0, frontier0 = self._sssp_seed_arrays(sources,
                                                          padded_picks)
                eng = self._shared_engine("sssp", sssp_program)
                res = multi_fn(self.layout, sources, engine=eng,
                               dist0=dist0, frontier0=frontier0, **extra)
            else:                      # bfs: the warm-startable program
                levels, parents, frontier0 = self._bfs_seed_arrays(
                    sources, padded_picks)
                eng = self._shared_engine("bfs_seeded", bfs_seeded_program)
                res = bfs_seeded_multi(self.layout, sources, engine=eng,
                                       seed_levels=levels,
                                       seed_parents=parents,
                                       frontiers=frontier0, **extra)
        else:
            eng = self._shared_engine(app, make_program)
            res = multi_fn(self.layout, sources, engine=eng, **extra)
        wall = time.perf_counter() - t0
        iters = len(res["stats"])
        if picks is not None:
            # iteration savings vs. the landmark's own cold convergence
            # (the best cold-run proxy available without re-running cold)
            lm_iters = max(int(p[1]["meta"]["iters"])
                           for p in picks if p is not None)
            saved = max(0, lm_iters - iters)
            if obs.enabled():
                obs.event("seeded_batch", app=app, layout=self._layout_tag,
                          batch=len(run),
                          seeded=sum(p is not None for p in picks),
                          iters=iters, saved_iters=saved)
                obs.inc("serve.seed_iters_saved", saved, app=app,
                        layout=self._layout_tag)
        if self.config.capture_landmarks and self._seedable(app):
            self._capture_landmarks(app, extra, distinct, res, iters)
        if obs.enabled():
            obs.event("serve_batch", app=app, layout=self._layout_tag,
                      batch=len(run), distinct_sources=len(lane_of),
                      width=len(sources), wall_s=wall)
            obs.observe("serve.batch_wall_s", wall, app=app,
                        layout=self._layout_tag)
            # per-query end-to-end latency of a fused batch is the batch
            # wall: every lane waits for the union frontier to drain
            for _ in run:
                obs.observe("serve.query_wall_s", wall, app=app,
                            layout=self._layout_tag)
        for q in run:
            i = lane_of[int(q.params["source"])]
            # copy the row out of the [B, n] batch result: a view would
            # pin the whole batch in memory for the cache's lifetime.
            # 'stats' is batch-level (BatchIterStats of the shared
            # iteration loop — per-lane IterStats don't exist on the
            # fused path); each query gets its own list copy
            out = {k: (np.array(v[i]) if k != "stats" else list(v))
                   for k, v in res.items()}
            self._note_cache(False, q.app)
            key = self._result_key(q)
            if key is not None:
                self.cache.put(key, out)
            q.result = out
            self.done.append(q)

    # ---- single-query path (overrides + non-batchable apps) -----------
    def _run_query(self, q: GraphQuery) -> dict:
        from ..apps.bfs import bfs, bfs_program
        from ..apps.cc import cc_program, connected_components
        from ..apps.nibble import nibble
        from ..apps.pagerank import pagerank
        from ..apps.sssp import sssp, sssp_program
        from ..apps.sssp_parents import (sssp_parents_program,
                                         sssp_with_parents)
        p = dict(q.params)
        # a query overriding an engine-construction parameter cannot share
        # the server engine (all three are baked in at Engine construction)
        custom = bool(self.ENGINE_KEYS & p.keys())
        mode = p.pop("mode", self.mode)
        backend = p.pop("backend", self.backend)
        bw_ratio = p.pop("bw_ratio", None)
        shared = {"bfs": (bfs, bfs_program), "sssp": (sssp, sssp_program),
                  "cc": (connected_components, cc_program),
                  "sssp_parents": (sssp_with_parents,
                                   sssp_parents_program)}
        if q.app in shared:
            app_fn, make_program = shared[q.app]
            if custom:
                # dedicated engine: not every app fn forwards bw_ratio
                from ..core.engine import Engine
                eng = Engine(self.layout, make_program(), mode=mode,
                             backend=backend,
                             **({"bw_ratio": bw_ratio}
                                if bw_ratio is not None else {}))
                return app_fn(self.layout, engine=eng, **p)
            return app_fn(self.layout, engine=self._shared_engine(
                q.app, make_program), **p)
        if q.app == "pagerank":
            # damping is baked into the program: no engine sharing
            return pagerank(self.layout, backend=backend,
                            mode="dc" if mode == "hybrid" else mode, **p)
        if q.app == "nibble":
            return nibble(self.layout, backend=backend, mode=mode, **p)
        raise ValueError(f"unknown graph app {q.app!r}")

    # ---- async warming -------------------------------------------------
    def _warm_compute(self, app, extra, source):
        """Warmer callback: converge ``source`` cold on the shared
        engine, store its state as a landmark AND its exact result (the
        repeat traffic that made it hot will hit the result entry)."""
        from ..apps.bfs import bfs_multi, bfs_program
        from ..apps.sssp import sssp_multi, sssp_program
        multi = {"bfs": (bfs_multi, bfs_program),
                 "sssp": (sssp_multi, sssp_program)}
        if app not in multi or not self._seedable(app):
            return
        multi_fn, make_program = multi[app]
        eng = self._shared_engine(app, make_program)
        res = multi_fn(self.layout, [int(source)], engine=eng, **extra)
        self._capture_landmarks(app, extra, [int(source)], res,
                                len(res["stats"]))
        row = {k: (np.array(v[0]) if k != "stats" else list(v))
               for k, v in res.items()}
        key = cache_lib.result_key(self._layout_tag, app,
                                   dict(extra, source=int(source)))
        if key is not None:
            self.cache.put(key, row)

    def _maybe_warm(self):
        """Give the warmer its per-tick budget (``ServeConfig
        .warm_budget`` jobs) after every :meth:`step` drain.  The budget
        runs whether or not the queue is empty — the old idle-only rule
        starved warming forever under sustained traffic, which is exactly
        when hot sources exist; a small fixed budget bounds the latency
        tax per tick instead."""
        if self.warmer is None:
            return
        self.warmer.scan()
        if self.warmer.pending:
            self.warmer.drain(self._warm_compute)

    def submit(self, q: GraphQuery):
        self.queue.append(q)
        if self.warmer is not None and q.app in self.SEEDED_FIELDS \
                and self._batch_sig(q) is not None:
            extra = {k: v for k, v in q.params.items() if k != "source"}
            self.warmer.note_query(q.app, extra, int(q.params["source"]))
        if obs.enabled():
            obs.set_gauge("serve.queue_depth", len(self.queue),
                          layout=self._layout_tag)

    def step(self) -> bool:
        """One scheduler tick: answer the head query — together with every
        queued query batchable with it when its app supports batching —
        consulting the result cache first; every tick ends with the
        async warmer's bounded per-tick budget."""
        if not self.queue:
            return False
        q = self.queue.popleft()
        sig = self._batch_sig(q)
        if sig is not None:
            batch, rest = [q], []
            for other in self.queue:
                if len(batch) < self.max_batch \
                        and self._batch_sig(other) == sig:
                    batch.append(other)
                else:
                    rest.append(other)
            self.queue = collections.deque(rest)
            if obs.enabled():
                obs.set_gauge("serve.queue_depth", len(self.queue),
                              layout=self._layout_tag)
            self._run_batch(batch)
            self._maybe_warm()
            return True
        cached = self._result_get(q)
        if cached is not None:
            self._note_cache(True, q.app)
            if obs.enabled():
                obs.event("serve_query", app=q.app,
                          layout=self._layout_tag, cached=True, wall_s=0.0)
            q.result = cached
        else:
            self._note_cache(False, q.app)
            t0 = time.perf_counter()
            q.result = self._run_query(q)
            wall = time.perf_counter() - t0
            if obs.enabled():
                obs.event("serve_query", app=q.app,
                          layout=self._layout_tag, cached=False,
                          wall_s=wall)
                obs.observe("serve.query_wall_s", wall, app=q.app,
                            layout=self._layout_tag)
            key = self._result_key(q)
            if key is not None:
                self.cache.put(key, q.result)
        if obs.enabled():
            obs.set_gauge("serve.queue_depth", len(self.queue),
                          layout=self._layout_tag)
        self.done.append(q)
        self._maybe_warm()
        return True

    def run(self):
        while self.step():
            pass
        return self.done
