"""Serving tier: LM continuous batching + graph-analytics query serving.

:class:`ServeConfig` is the one configuration object of the graph query
server; :mod:`repro.serve.cache` is the cache subsystem behind it
(backend protocol, semantic entries, async warmer).
"""
import dataclasses
from typing import Any, Optional


@dataclasses.dataclass
class ServeConfig:
    """Consolidated :class:`GraphQueryServer` configuration.

    The server used to take a growing pile of keyword arguments; they
    now live here (passing them as keywords still works but emits a
    ``DeprecationWarning``).  Construct with only the fields you care
    about — defaults match the old keyword defaults.

    Engine / batching:
      backend:       kernel-backend name (None = registry default).
      mode:          scatter-gather mode ('hybrid' | 'dc' | 'sc').
      max_batch:     max queries fused into one batched run.
      sharded/mesh:  distributed serving (both or neither).
      wire_bf16 / wire_bitmap: dist-only wire compression toggles.

    Caching (see :mod:`repro.serve.cache` for the key space and the
    invalidation rule):
      cache_size:    backend capacity in entries (result + semantic
                     entries share it).
      cache_backend: a :class:`repro.serve.cache.CacheBackend` instance,
                     a directory path (-> :class:`DiskCache`), or None
                     (-> :class:`MemoryLRU`).
      semantic:      enable the partition-level semantic cache: converged
                     per-partition state is captured as landmarks and
                     misses near a landmark run landmark-seeded.
      capture_landmarks: store every computed batch lane's converged
                     state as a landmark (otherwise only the async
                     warmer creates landmarks).
      seed_max_distance: only seed from a landmark within this distance
                     of the query source (None = any reachable landmark).
      warm_threshold: source frequency at which the async warmer
                     precomputes a landmark.
      warm_budget:   landmark precomputations per idle scheduler tick.
    """

    backend: Optional[str] = None
    mode: str = "hybrid"
    max_batch: int = 64
    cache_size: int = 128
    sharded: Any = None
    mesh: Any = None
    wire_bf16: bool = False
    wire_bitmap: bool = True
    cache_backend: Any = None
    semantic: bool = True
    capture_landmarks: bool = True
    seed_max_distance: Optional[float] = None
    warm_threshold: int = 3
    warm_budget: int = 1


# ServeConfig must exist before .engine executes (it imports it back
# from this partially-initialized package)
from .cache import (CacheBackend, CacheWarmer, DiskCache, MemoryLRU,
                    SemanticCache, make_backend)
from .engine import (GraphQuery, GraphQueryServer, Request, Server,
                     decode_step, init_cache, prefill)

__all__ = [
    "ServeConfig", "CacheBackend", "CacheWarmer", "DiskCache", "MemoryLRU",
    "SemanticCache", "make_backend", "GraphQuery", "GraphQueryServer",
    "Request", "Server", "decode_step", "init_cache", "prefill",
]
