from .engine import (GraphQuery, GraphQueryServer, Request, Server,
                     decode_step, init_cache, prefill)
