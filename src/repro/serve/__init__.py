from .engine import Server, Request, init_cache, prefill, decode_step
