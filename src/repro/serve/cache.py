"""Partition-level semantic caching for the graph-analytics serving tier.

The PR 5 result cache only hit on an exact ``(layout, app, params)``
match.  This module generalizes it in two directions:

1. **A formal cache-backend protocol.**  :class:`CacheBackend` is the
   storage contract every serve-tier cache speaks — the exact-match
   result cache and the semantic state cache are both *clients* of the
   same protocol, so in-memory LRU (:class:`MemoryLRU`) and disk-backed
   (:class:`DiskCache`, JSONL index + ``.npz`` payloads) storage are
   interchangeable behind either.

2. **Partition-level semantic entries.**  :class:`SemanticCache` stores
   *converged per-partition state* — BFS level/parent vectors per source,
   SSSP distance vectors per source, PageRank vectors per damping factor
   — chunked by the partitions the query actually touched (GPOP's thesis
   that partitions are the right locality granularity, applied *across*
   queries).  A cached source is a **landmark**: a new query whose source
   is within reach of a landmark is *seeded* from the cached state
   instead of a cold frontier, and converges in fewer or equal
   iterations while remaining exactly correct.

Key space (documented contract; both clients share one namespace so a
single backend instance may serve both):

  ``res|<layout>|<app>|<canon params>``
      an exact-match query result (the PR 5 LRU entries);
  ``sem|<layout>|<app>|<canon extra params>|src=<landmark>``
      converged per-partition state from landmark source ``<landmark>``
      (``extra params`` = everything except the source, e.g. SSSP with a
      custom ``max_iters``, or ``damping`` for PageRank vectors).

``<layout>`` is the server's *content-derived* layout tag
(:func:`layout_tag`), so the invalidation rule is **scoped, not
wholesale**:

* a plain ``swap_layout(new)`` evicts *nothing* — entries are invisible
  under the new tag's key namespace but stay resident, so swapping back
  to a layout the backend has seen (A -> B -> A) revalidates its entries
  for free;
* a delta swap (``swap_layout(new, delta=...)``) evicts only what the
  delta actually invalidated: the old tag's exact-match ``res|`` entries
  (a global answer is stale under any edge edit) and the ``sem|``
  entries whose stored partitions intersect a partition whose content
  tag (:func:`partition_tags`) changed; clean-partition entries of an
  insertion-only delta are *migrated* to the new tag (still-sound
  upper-bound seeds — see ``serve/engine.py``);
* wholesale :meth:`CacheBackend.clear` remains the contract of
  ``clear_cache()`` only.

Prefix-scoped eviction is part of the protocol
(:meth:`CacheBackend.evict_prefix`, with a ``keys()``-scan default), so
backends can specialize it without the serve tier caring.

Why landmark seeding is exactly correct (monotone min-monoids)
--------------------------------------------------------------

For a min-monoid vertex program (BFS, SSSP) the converged state from
source ``s`` is the least fixpoint ``d_s``.  Relaxation from ANY initial
state that is a pointwise *upper bound* of ``d_s`` (with ``d_s(s) = 0``)
converges to exactly ``d_s``: the fixpoint of Bellman-Ford relaxation
from ``init`` is ``min_u (init[u] + dist(u, v))``, which the upper-bound
property squeezes to ``d_s(v)`` from both sides.  A landmark ``L`` with
converged state ``d_L`` supplies such a bound on *symmetric* graphs via
the triangle inequality::

    d_s(v)  <=  d_s(L) + d_L(v)  =  d_L(s) + d_L(v)

so seeding ``init[v] = d_L(v) + d_L(s)`` (and ``init[s] = 0``) with the
initial frontier set to every vertex the landmark reached is safe: stale
upper bounds are *corrected*, never believed.  Symmetry is required
twice — it turns ``d_s(L)`` into the known ``d_L(s)``, and it makes
"unreached by L" imply "unreached by s" (so untouched partitions keep
the identity/unreachable value exactly).  The serve tier auto-detects
symmetry from the layout's CSR (cached per layout) and silently skips
seeding on directed graphs.

BFS needs one extra care: the stock first-visit program derives levels
from the iteration counter, which a warm start breaks.  Seeded BFS
therefore runs the packed lexicographic ``(level, parent)`` min-monoid
relaxation (:func:`repro.apps.bfs.bfs_seeded_program`), whose cold run
is bit-identical to stock BFS — see the proof sketch in that docstring.

Async warming
-------------

:class:`CacheWarmer` turns query-log statistics (per-app source
frequencies, mirrored into :mod:`repro.obs` as the ``serve.source_freq``
counter) into landmark precomputation jobs.  The serve tier drains a
small fixed budget of jobs at the end of *every*
:meth:`GraphQueryServer.step` tick — bounded, so the latency tax per
tick is capped, but unconditional, so sustained traffic (exactly the
regime that produces hot sources) cannot starve warming.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import io
import json
import os
import threading
import time
from typing import Any, Dict, Iterable, Optional, Protocol, runtime_checkable

import numpy as np

from .. import obs

# ----------------------------------------------------------------------
# key construction (the documented, shared key space)
# ----------------------------------------------------------------------


def canon_params(params: dict) -> Optional[str]:
    """Canonical, deterministic string for a query's param dict, or None
    when a value defies canonicalization (such a query is not cacheable).
    Arrays / lists / tuples flatten to tuples; dict order is irrelevant."""
    def canon(v):
        if isinstance(v, (list, tuple, np.ndarray)):
            return tuple(np.asarray(v).reshape(-1).tolist())
        if isinstance(v, (np.integer,)):
            return int(v)
        if isinstance(v, (np.floating,)):
            return float(v)
        return v
    try:
        items = tuple(sorted((k, canon(v)) for k, v in params.items()))
        hash(items)
    except TypeError:
        return None
    return repr(items)


def result_key(layout_tag: str, app: str, params: dict) -> Optional[str]:
    """Exact-match result entry: ``res|<layout>|<app>|<canon params>``."""
    canon = canon_params(params)
    if canon is None:
        return None
    return f"res|{layout_tag}|{app}|{canon}"


def semantic_key(layout_tag: str, app: str, extra_params: dict,
                 source: int) -> Optional[str]:
    """Converged-state entry from landmark ``source``:
    ``sem|<layout>|<app>|<canon extra>|src=<source>``."""
    canon = canon_params(extra_params)
    if canon is None:
        return None
    return f"sem|{layout_tag}|{app}|{canon}|src={int(source)}"


def semantic_prefix(layout_tag: str, app: str, extra_params: dict) -> str:
    canon = canon_params(extra_params)
    return f"sem|{layout_tag}|{app}|{canon}|src="


# ----------------------------------------------------------------------
# the backend protocol
# ----------------------------------------------------------------------


@runtime_checkable
class CacheBackend(Protocol):
    """Storage contract of every serve-tier cache.

    Values are dicts whose leaves are ``np.ndarray`` or JSON-able
    scalars / lists / nested dicts (the :class:`DiskCache` round-trip
    preserves arrays bit-exactly and everything else as plain JSON).
    Returned values must be treated as read-only by callers.

    Implementations must provide:

    * ``get(key) -> value | None`` — also refreshes LRU recency;
    * ``put(key, value)`` — inserts/overwrites, evicting least-recently
      -used entries beyond ``capacity``;
    * ``evict(key) -> bool`` — targeted drop, True when present;
    * ``evict_prefix(prefix) -> int`` — drop every key under a prefix,
      returning the count.  **This is the serve tier's invalidation
      primitive**: ``swap_layout(delta=...)`` evicts only the old layout
      tag's superseded prefixes (see the module docstring) instead of
      clearing the backend;
    * ``clear()`` — drop everything.  The contract of ``clear_cache()``
      *only*: layout swaps must never call it, because entries keyed
      under other layout tags stay valid for those layouts;
    * ``keys() -> list[str]`` — snapshot in LRU order (oldest first);
    * ``stats() -> dict`` — at least ``hits / misses / puts / evictions
      / entries``;
    * ``__len__``.
    """

    def get(self, key: str) -> Optional[dict]: ...
    def put(self, key: str, value: dict) -> None: ...
    def evict(self, key: str) -> bool: ...
    def evict_prefix(self, prefix: str) -> int: ...
    def clear(self) -> None: ...
    def keys(self) -> list: ...
    def stats(self) -> dict: ...
    def __len__(self) -> int: ...


def evict_prefix(backend, prefix: str) -> int:
    """Prefix eviction against any backend: dispatches to the backend's
    own ``evict_prefix`` when it has one, otherwise falls back to a
    ``keys()`` scan — so structural third-party backends that predate the
    protocol method still work under scoped invalidation."""
    fn = getattr(backend, "evict_prefix", None)
    if fn is not None:
        return int(fn(prefix))
    return sum(1 for key in list(backend.keys())
               if isinstance(key, str) and key.startswith(prefix)
               and backend.evict(key))


class _StatsBase:
    """Shared hit/miss/put/eviction accounting."""

    def __init__(self):
        self._hits = 0
        self._misses = 0
        self._puts = 0
        self._evictions = 0
        self._lock = threading.Lock()

    def stats(self) -> dict:
        return {"hits": self._hits, "misses": self._misses,
                "puts": self._puts, "evictions": self._evictions,
                "entries": len(self)}

    def evict_prefix(self, prefix: str) -> int:
        """Default ``keys()``-scan implementation of the protocol's
        prefix eviction; backends with an indexed key space may
        override."""
        return sum(1 for key in list(self.keys())
                   if isinstance(key, str) and key.startswith(prefix)
                   and self.evict(key))


class MemoryLRU(_StatsBase):
    """In-memory LRU :class:`CacheBackend` (the PR 5 OrderedDict,
    formalized).  ``capacity`` counts entries; values are held by
    reference, so callers must treat them as read-only."""

    def __init__(self, capacity: int = 128):
        super().__init__()
        self.capacity = int(capacity)
        self._d: "collections.OrderedDict[str, dict]" = \
            collections.OrderedDict()

    def get(self, key):
        with self._lock:
            if key is None or key not in self._d:
                self._misses += 1
                return None
            self._d.move_to_end(key)
            self._hits += 1
            return self._d[key]

    def put(self, key, value):
        if key is None:
            return
        with self._lock:
            self._d[key] = value
            self._d.move_to_end(key)
            self._puts += 1
            while len(self._d) > self.capacity:
                self._d.popitem(last=False)
                self._evictions += 1

    def evict(self, key) -> bool:
        with self._lock:
            if key in self._d:
                del self._d[key]
                self._evictions += 1
                return True
            return False

    def clear(self):
        with self._lock:
            self._d.clear()

    def keys(self):
        with self._lock:
            return list(self._d)

    def __len__(self):
        return len(self._d)


class DiskCache(_StatsBase):
    """Disk-backed :class:`CacheBackend`: one ``.npz`` payload per entry
    plus an append-only JSONL operation log (``index.jsonl``) that is
    replayed on construction, so a warm cache survives process restarts.

    The op-log is *compacted* on open whenever it has grown well past the
    live entry count (heavy put/evict churn appends one record per op and
    never rewrites): the replayed state is rewritten atomically as one
    ``put`` record per live entry, and any ``.npz`` payload in the
    directory that no live entry references (crashed writes, records
    dropped by a ``clear``) is unlinked.  Steady-state disk usage is
    therefore O(live entries), not O(operation history).

    Array leaves of the value dict are stored in the npz (bit-exact
    round-trip, no pickling); every other leaf goes through JSON —
    dataclasses and tuples come back as plain dicts / lists, which is
    the documented metadata contract.  Nested dicts are flattened with
    ``/`` separators on the npz side."""

    _ARRAY = "a/"          # npz member prefix for array leaves
    # compact when the op-log is both non-trivial and dominated by dead
    # records: ops > max(COMPACT_MIN_OPS, COMPACT_FACTOR * live entries)
    COMPACT_MIN_OPS = 16
    COMPACT_FACTOR = 4

    def __init__(self, path, capacity: int = 64):
        super().__init__()
        self.path = str(path)
        self.capacity = int(capacity)
        os.makedirs(self.path, exist_ok=True)
        self._index = os.path.join(self.path, "index.jsonl")
        self._d: "collections.OrderedDict[str, str]" = \
            collections.OrderedDict()        # key -> npz filename
        n_ops = self._replay()
        if n_ops > max(self.COMPACT_MIN_OPS,
                       self.COMPACT_FACTOR * len(self._d)):
            self._compact()

    # ---- op-log persistence ----
    def _replay(self) -> int:
        """Rebuild the index from the op-log; returns the op count."""
        if not os.path.exists(self._index):
            return 0
        n_ops = 0
        with open(self._index) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue                  # torn tail write
                n_ops += 1
                op = rec.get("op")
                if op == "put":
                    self._d[rec["key"]] = rec["file"]
                    self._d.move_to_end(rec["key"])
                elif op == "evict":
                    self._d.pop(rec.get("key"), None)
                elif op == "clear":
                    self._d.clear()
        # drop index entries whose payload vanished out from under us
        for k in [k for k, fn in self._d.items()
                  if not os.path.exists(os.path.join(self.path, fn))]:
            del self._d[k]
        return n_ops

    def _compact(self):
        """Rewrite the op-log as one ``put`` per live entry (atomically,
        via a tmp file + rename) and unlink payloads no entry references."""
        now = time.time()
        tmp = self._index + ".tmp"
        with open(tmp, "w") as f:
            for key, fname in self._d.items():     # LRU order preserved
                f.write(json.dumps({"op": "put", "key": key,
                                    "file": fname, "ts": now}) + "\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self._index)
        live = set(self._d.values())
        for fname in os.listdir(self.path):
            if fname.endswith(".npz") and fname not in live:
                self._unlink(fname)

    def _log(self, rec: dict):
        with open(self._index, "a") as f:
            f.write(json.dumps(rec) + "\n")
            f.flush()

    def _fname(self, key: str) -> str:
        return hashlib.sha1(key.encode()).hexdigest()[:20] + ".npz"

    # ---- value (de)serialization ----
    def _flatten(self, value: dict, prefix=""):
        arrays, meta = {}, {}
        for k, v in value.items():
            name = f"{prefix}{k}"
            if isinstance(v, np.ndarray):
                arrays[self._ARRAY + name] = v
            elif isinstance(v, dict):
                sub_a, sub_m = self._flatten(v, prefix=name + "/")
                arrays.update(sub_a)
                if sub_m:
                    meta[k] = sub_m
            else:
                if dataclasses.is_dataclass(v):
                    v = dataclasses.asdict(v)
                elif isinstance(v, (list, tuple)):
                    v = [dataclasses.asdict(x) if dataclasses.is_dataclass(x)
                         else x for x in v]
                meta[k] = v
        return arrays, meta

    def _write(self, fname: str, value: dict):
        arrays, meta = self._flatten(value)
        buf = io.BytesIO()
        np.savez(buf, __meta__=np.frombuffer(
            json.dumps(meta, default=str).encode(), dtype=np.uint8),
            **arrays)
        with open(os.path.join(self.path, fname), "wb") as f:
            f.write(buf.getvalue())

    def _read(self, fname: str) -> Optional[dict]:
        fp = os.path.join(self.path, fname)
        if not os.path.exists(fp):
            return None
        with np.load(fp, allow_pickle=False) as z:
            meta = json.loads(bytes(z["__meta__"]).decode())
            out = dict(meta)
            for name in z.files:
                if not name.startswith(self._ARRAY):
                    continue
                node, parts = out, name[len(self._ARRAY):].split("/")
                for p in parts[:-1]:
                    node = node.setdefault(p, {})
                node[parts[-1]] = z[name]
        return out

    # ---- protocol ----
    def get(self, key):
        with self._lock:
            if key is None or key not in self._d:
                self._misses += 1
                return None
            value = self._read(self._d[key])
            if value is None:                 # payload vanished on disk
                del self._d[key]
                self._misses += 1
                return None
            self._d.move_to_end(key)
            self._hits += 1
            return value

    def put(self, key, value):
        if key is None:
            return
        with self._lock:
            fname = self._fname(key)
            self._write(fname, value)
            self._d[key] = fname
            self._d.move_to_end(key)
            self._log({"op": "put", "key": key, "file": fname,
                       "ts": time.time()})
            self._puts += 1
            while len(self._d) > self.capacity:
                old_key, old_fname = self._d.popitem(last=False)
                self._unlink(old_fname)
                self._log({"op": "evict", "key": old_key})
                self._evictions += 1

    def evict(self, key) -> bool:
        with self._lock:
            fname = self._d.pop(key, None)
            if fname is None:
                return False
            self._unlink(fname)
            self._log({"op": "evict", "key": key})
            self._evictions += 1
            return True

    def clear(self):
        with self._lock:
            for fname in self._d.values():
                self._unlink(fname)
            self._d.clear()
            self._log({"op": "clear"})

    def _unlink(self, fname: str):
        try:
            os.unlink(os.path.join(self.path, fname))
        except OSError:
            pass

    def keys(self):
        with self._lock:
            return list(self._d)

    def __len__(self):
        return len(self._d)


def make_backend(spec, capacity: int) -> CacheBackend:
    """Resolve a backend spec: an instance passes through; ``None`` ->
    :class:`MemoryLRU`; a path string -> :class:`DiskCache` at it."""
    if spec is None:
        return MemoryLRU(capacity)
    if isinstance(spec, str):
        return DiskCache(spec, capacity=capacity)
    return spec


# ----------------------------------------------------------------------
# partition-level semantic entries
# ----------------------------------------------------------------------


class SemanticCache:
    """Converged per-partition state, keyed by landmark source.

    One entry stores, for every partition the landmark's computation
    touched, the ``[q]`` slice of each converged state field — plus the
    landmark's own convergence metadata (iteration count, touched-vertex
    count).  Vertices in untouched partitions are implicit (the field's
    ``fill`` identity), which is what makes the entries partition-level:
    a BFS from a well-connected landmark stores nearly everything, a
    Nibble-style local query stores a handful of ``[q]`` blocks.
    """

    def __init__(self, backend: CacheBackend, layout_tag: str,
                 k: int, q: int, n_pad: int):
        self.backend = backend
        self.layout_tag = layout_tag
        self.k, self.q, self.n_pad = int(k), int(q), int(n_pad)

    # ---- store ----
    def put_state(self, app: str, extra_params: dict, source: int,
                  fields: Dict[str, np.ndarray], touched: np.ndarray,
                  fills: Dict[str, Any], iters: int) -> Optional[str]:
        """Store converged ``fields`` (each ``[n_pad]``) from ``source``.

        ``touched`` is a ``[n_pad]`` bool mask of vertices the query
        reached; only partitions containing a touched vertex are stored.
        ``fills`` gives the per-field identity value reconstructed into
        untouched partitions on expansion."""
        key = semantic_key(self.layout_tag, app, extra_params, source)
        if key is None:
            return None
        touched = np.asarray(touched, bool)
        parts = np.unique(
            np.nonzero(touched)[0].astype(np.int64) // self.q)
        parts = parts.astype(np.int32)
        entry = {
            "parts": parts,
            "meta": {"source": int(source), "app": app,
                     "iters": int(iters),
                     "touched": int(touched.sum()),
                     "fills": {k: (None if v is None else float(v))
                               for k, v in fills.items()},
                     "fields": sorted(fields)},
        }
        for name, vec in fields.items():
            vec = np.asarray(vec)
            assert vec.shape == (self.n_pad,), (name, vec.shape)
            entry[f"f_{name}"] = \
                vec.reshape(self.k, self.q)[parts].copy()
        self.backend.put(key, entry)
        return key

    # ---- read ----
    def landmarks(self, app: str, extra_params: dict) -> list:
        """Landmark sources with a cached entry for (app, extra)."""
        prefix = semantic_prefix(self.layout_tag, app, extra_params)
        out = []
        for key in self.backend.keys():
            if key.startswith(prefix):
                try:
                    out.append(int(key[len(prefix):]))
                except ValueError:
                    pass
        return out

    def get_state(self, app: str, extra_params: dict,
                  source: int) -> Optional[dict]:
        key = semantic_key(self.layout_tag, app, extra_params, source)
        return self.backend.get(key) if key is not None else None

    def value_at(self, entry: dict, field: str, vertex: int):
        """One field value at one vertex, or the fill for untouched
        partitions (no full-vector materialization)."""
        parts = np.asarray(entry["parts"])
        p = int(vertex) // self.q
        hit = np.nonzero(parts == p)[0]
        if len(hit) == 0:
            return entry["meta"]["fills"].get(field)
        return entry[f"f_{field}"][int(hit[0]), int(vertex) % self.q]

    def expand(self, entry: dict, field: str, fill) -> np.ndarray:
        """Full ``[n_pad]`` vector: ``fill`` in untouched partitions,
        the stored per-partition slices elsewhere."""
        stored = np.asarray(entry[f"f_{field}"])
        full = np.full((self.k, self.q), fill, dtype=stored.dtype)
        parts = np.asarray(entry["parts"], np.int64)
        if len(parts):
            full[parts] = stored
        return full.reshape(self.n_pad)

    def best_landmark(self, app: str, extra_params: dict, source: int,
                      dist_field: str,
                      max_distance: Optional[float] = None):
        """The cached landmark nearest to ``source`` (by the landmark's
        own converged ``dist_field`` value at ``source``), or None when
        no landmark reaches it (or none is within ``max_distance``).

        Returns ``(landmark_source, entry, d_ls)``."""
        best = None
        for lm in self.landmarks(app, extra_params):
            entry = self.get_state(app, extra_params, lm)
            if entry is None:
                continue
            d = self.value_at(entry, dist_field, source)
            if d is None or not np.isfinite(d) or d < 0:
                continue
            d = float(d)
            if max_distance is not None and d > max_distance:
                continue
            if best is None or d < best[2]:
                best = (lm, entry, d)
        return best


# ----------------------------------------------------------------------
# async cache warmer
# ----------------------------------------------------------------------


class CacheWarmer:
    """Queue-driven landmark precomputation from query-log statistics.

    The serve tier mirrors every submitted source into the
    ``serve.source_freq`` obs counter (labeled by app + layout) *and*
    into this warmer's local frequency table (so warming still works at
    ``REPRO_OBS=0``).  :meth:`scan` promotes sources whose frequency
    reached ``threshold`` and which are not yet landmarks into a pending
    deque; :meth:`drain` pops up to ``budget`` jobs and runs the cold
    computation through a caller-supplied ``compute(app, extra, source)``
    callback that converges the state and stores it into the semantic
    cache.  The serve tier calls ``scan() + drain()`` at the end of
    every :meth:`GraphQueryServer.step` tick — the small fixed budget
    bounds the per-tick latency tax, and running it unconditionally
    (instead of only on idle ticks) keeps sustained traffic from
    starving the warmer forever."""

    def __init__(self, semantic: SemanticCache, threshold: int = 3,
                 budget: int = 1, max_pending: int = 64):
        self.semantic = semantic
        self.threshold = int(threshold)
        self.budget = int(budget)
        self.max_pending = int(max_pending)
        self.pending = collections.deque()
        self._freq = collections.Counter()     # (app, canon extra, src)
        self._extra = {}                       # (app, canon) -> extra dict
        self._done = set()

    # ---- query-log statistics ----
    def note_query(self, app: str, extra_params: dict, source: int):
        canon = canon_params(extra_params)
        if canon is None:
            return
        self._freq[(app, canon, int(source))] += 1
        self._extra[(app, canon)] = dict(extra_params)
        if obs.enabled():
            obs.inc("serve.source_freq", app=app,
                    layout=self.semantic.layout_tag, source=int(source))

    def frequencies(self, app: str, extra_params: dict) -> dict:
        canon = canon_params(extra_params)
        return {s: c for (a, x, s), c in self._freq.items()
                if a == app and x == canon}

    # ---- job management ----
    def scan(self):
        """Promote hot non-landmark sources into the pending queue."""
        for (app, canon, src), count in self._freq.items():
            if count < self.threshold:
                continue
            job = (app, canon, src)
            if job in self._done or job in self.pending:
                continue
            if len(self.pending) >= self.max_pending:
                break
            extra = self._extra[(app, canon)]
            if semantic_key(self.semantic.layout_tag, app, extra,
                            src) in self.semantic.backend.keys():
                self._done.add(job)
                continue
            self.pending.append(job)

    def drain(self, compute, budget: Optional[int] = None) -> int:
        """Run up to ``budget`` pending precomputations through
        ``compute(app, extra_params, source)`` (which stores the result
        into the semantic cache).  Returns the number of jobs run."""
        n = 0
        budget = self.budget if budget is None else budget
        while self.pending and n < budget:
            app, canon, src = self.pending.popleft()
            extra = self._extra.get((app, canon), {})
            t0 = time.perf_counter()
            try:
                compute(app, extra, src)
            finally:
                self._done.add((app, canon, src))
            if obs.enabled():
                obs.event("cache_warm", app=app,
                          layout=self.semantic.layout_tag,
                          source=int(src),
                          wall_s=time.perf_counter() - t0)
                obs.inc("serve.warmed_landmarks", app=app,
                        layout=self.semantic.layout_tag)
            n += 1
        return n

    def reset(self):
        self.pending.clear()
        self._freq.clear()
        self._extra.clear()
        self._done.clear()


# ----------------------------------------------------------------------
# symmetry detection (seeding precondition)
# ----------------------------------------------------------------------


def layout_is_symmetric(layout, weights: bool = True) -> bool:
    """True when the layout's CSR (restricted to the real ``n`` vertices)
    is symmetric — the precondition for landmark seeding (see the module
    docstring).  ``weights=True`` (the SSSP requirement) checks structure
    AND edge weights; ``weights=False`` (the BFS requirement — hop
    distance ignores weights) checks structure only.  O(m log m),
    computed once per layout by the serve tier and cached there."""
    import scipy.sparse as sp
    n = layout.n
    indptr = np.asarray(layout.csr_indptr)[:n + 1]
    lo, hi = int(indptr[0]), int(indptr[-1])
    indices = np.asarray(layout.csr_indices)[lo:hi]
    if np.any(indices >= n):          # edges into padding never exist,
        return False                  # but be safe about sentinels
    data = (np.asarray(layout.csr_w)[lo:hi]
            if weights and layout.csr_w is not None
            else np.ones(hi - lo, np.float32))
    a = sp.csr_matrix((data, indices, indptr - lo), shape=(n, n))
    return (a != a.T).nnz == 0


def layout_tag(layout) -> str:
    """Content-derived layout identity for cache keys and metric labels.

    Unlike ``id(layout)``, two layouts built from the same graph with the
    same partitioning share a tag — which is what lets a
    :class:`DiskCache` survive process restarts and still hit."""
    h = hashlib.sha1()
    h.update(np.asarray([layout.n, layout.k, layout.q],
                        np.int64).tobytes())
    h.update(np.ascontiguousarray(layout.csr_indptr).tobytes())
    h.update(np.ascontiguousarray(layout.csr_indices).tobytes())
    if layout.csr_w is not None:
        h.update(np.ascontiguousarray(layout.csr_w).tobytes())
    return h.hexdigest()[:16]


def partition_tags(layout) -> list:
    """Per-partition content tags: ``tags[p]`` changes iff partition
    ``p``'s out-edges *or* in-edges (with weights) changed.

    This is the scope of delta invalidation: a partition's converged
    state can only be perturbed directly through its own adjacency, so a
    semantic-cache entry whose stored partitions all kept their tags
    survives the swap (as a still-sound upper bound for insertion-only
    deltas — the migration rule in ``serve/engine.py``).  ``apply_delta``
    reuses clean partitions' CSR slices verbatim, which is what makes
    these tags stable across small deltas by construction."""
    n, k, q = layout.n, layout.k, layout.q
    indptr = np.asarray(layout.csr_indptr)[:n + 1]
    indices = np.asarray(layout.csr_indices)
    w = None if layout.csr_w is None else np.asarray(layout.csr_w)
    degs = np.diff(indptr)
    src = np.repeat(np.arange(n, dtype=np.int64), degs)
    dp = (indices.astype(np.int64) // q if q
          else np.zeros(len(indices), np.int64))
    in_order = np.argsort(dp, kind="stable")
    in_start = np.searchsorted(dp[in_order], np.arange(k + 1))
    tags = []
    for p in range(k):
        vs, ve = min(p * q, n), min((p + 1) * q, n)
        e0, e1 = int(indptr[vs]), int(indptr[ve])
        h = hashlib.sha1()
        h.update(np.ascontiguousarray(degs[vs:ve]).tobytes())
        h.update(np.ascontiguousarray(indices[e0:e1]).tobytes())
        if w is not None:
            h.update(np.ascontiguousarray(w[e0:e1]).tobytes())
        sel = in_order[in_start[p]:in_start[p + 1]]
        h.update(np.ascontiguousarray(src[sel]).tobytes())
        h.update(np.ascontiguousarray(indices[sel]).tobytes())
        if w is not None:
            h.update(np.ascontiguousarray(w[sel]).tobytes())
        tags.append(h.hexdigest()[:16])
    return tags
