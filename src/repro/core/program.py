"""The GPOP user API (paper §4.1), adapted to array semantics.

The paper steers applications through four scalar callbacks plus an optional
weight hook.  Here each callback is vectorized over the (padded) vertex space;
the engine applies the activity masks, so user code never sees parallelism,
partitioning, or communication — the same contract as the paper:

  scatter_fn(state)                 ≙ scatterFunc(node)    value sent to out-neighbors
  init_fn(state, it)                ≙ initFunc(node)       selective frontier continuity
  apply_fn(state, acc, touched, it) ≙ gatherFunc(val,node) fold result -> update + activate
  filter_fn(state, it)              ≙ filterFunc(node)     final frontier filtering
  apply_weight(vals, w)             ≙ applyWeight(val,wt)

``state`` is a pytree of per-vertex arrays with leading dim ``n_pad``.
``apply_weight`` must preserve the monoid identity (identity ∘ w = identity) —
true for the paper's usage (min-monoid with val+wt, add-monoid with val*wt).
The gather fold itself is the program's ``monoid`` (see monoid.py for why
associativity is required on TPU).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from . import monoid as monoid_lib


@dataclasses.dataclass
class VertexProgram:
    name: str
    monoid: monoid_lib.Monoid
    scatter_fn: Callable                      # (state) -> msgs[n_pad]
    apply_fn: Callable                        # (state, acc, touched, it) -> (state, activated)
    init_fn: Optional[Callable] = None        # (state, it) -> (state, keep)
    filter_fn: Optional[Callable] = None      # (state, it) -> (state, keep)
    apply_weight: Optional[Callable] = None   # (vals, w) -> vals
