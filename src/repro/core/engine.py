"""The PPM engine: scatter → initFrontier → exchange → gather → filter.

Single-device engine over a partition-centric :class:`repro.graph.layout.Layout`.
Each iteration follows paper Alg. 3/4 exactly:

  1. *Scatter*: active vertices produce messages.  Per-partition mode choice
     (Eq. 1 cost model):
       - **DC stream**: all PNG message slots of DC-mode partitions that have
         at least one active vertex are materialized (values only — the
         adjacency side ``msg_slot``/``edge_dst`` is static, the paper's
         pre-written ``dc_bin``).  Slots whose source vertex is inactive carry
         the monoid identity, which makes them exact no-ops in the fold — the
         array-semantics equivalent of the paper's "scatter the whole
         partition" correctness contract.
       - **SC stream**: active vertices of SC-mode partitions are compacted
         (``nonzero``) and their CSR adjacency expanded into a `(value, dst)`
         message list.  The buffers are sized by power-of-two *budgets* so the
         compute really is proportional to the active edge count (rounded up)
         — the static-shape realization of the paper's theoretical efficiency.
  2. *initFrontier*: ``init_fn`` on active vertices → selective continuity.
  3. *Gather*: one segmented monoid fold per stream into the (VMEM-resident,
     on TPU) vertex tile, plus a `touched` fold; ``apply_fn`` updates touched
     vertices and proposes activations.
  4. *filterFrontier*: ``filter_fn`` on the union frontier.

The 2-level active list appears as: per-partition active counts drive the mode
decision and exclude empty partitions entirely (gPartList); tile-level
predication inside the Pallas kernels skips edge tiles of inactive partitions
(binPartList).
"""
from __future__ import annotations

import time
import warnings
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from ..backend import registry as kregistry
from ..graph.layout import Layout
from .cost import CostModel
from .program import VertexProgram


def _tree_where(mask, new, old):
    def sel(a, b):
        m = mask.reshape(mask.shape + (1,) * (a.ndim - mask.ndim))
        return jnp.where(m, a, b)
    return jax.tree_util.tree_map(sel, new, old)


def _next_pow2(x: int) -> int:
    return 1 if x <= 1 else 1 << (int(x - 1).bit_length())


# The per-iteration stat records live in the obs schema
# (repro.obs.schema).  The old module-level aliases here are a
# deprecation shim: accessing them still works but warns — import from
# repro.obs.schema (or repro.obs) instead.  Internal code already does.
def __getattr__(name):
    if name in ("IterStats", "BatchIterStats"):
        import warnings
        warnings.warn(
            f"repro.core.engine.{name} is deprecated; import it from "
            "repro.obs.schema", DeprecationWarning, stacklevel=2)
        return getattr(obs, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def _compact_lane_index(lane_act: np.ndarray):
    """Surviving lane indices packed to the next power-of-two width.

    Padding repeats the first survivor, whose duplicate rows compute
    identical values, so scattering the packed results back with
    ``.at[idx].set`` is deterministic; the pow2 width keeps the per-width
    jit cache at log2(B) entries."""
    idx_r = np.nonzero(lane_act)[0]
    W = _next_pow2(len(idx_r))
    idx = np.concatenate([idx_r, np.full(W - len(idx_r), idx_r[0])])
    return jnp.asarray(idx, jnp.int32), W


def _run_batched_loop(step_for_width, states, active, max_iters: int,
                      until_empty: bool, collect_stats: bool,
                      engine_name: str = "core", program: str = "",
                      wire_bytes_fn=None):
    """Host-driven batched convergence loop shared by
    :meth:`Engine.run_batched` and
    :meth:`repro.dist.engine.DistEngine.run_batched`.

    ``step_for_width(W)`` returns the jitted batched iteration for lane
    width ``W`` — ``fn(states, active, it) -> (states, active)`` over
    ``[W, ...]`` leaves.  The *union* frontier drives convergence; between
    steps converged lanes are compacted out of the batch entirely (packed
    to pow2 widths via :func:`_compact_lane_index`).

    Telemetry (``repro.obs``): per-step ``batch_iter`` events and a
    step-wall histogram when ``collect_stats`` and obs are both on, and a
    ``lane_compaction`` event whenever converged lanes are repacked.
    Everything recorded is already host-resident (``lane_act`` drives the
    loop), so ``collect_stats=False`` adds zero device syncs regardless
    of the obs switch.  ``wire_bytes_fn(n_lanes)``, when given, prices
    the step's analytic exchange payload into the event."""
    B = active.shape[0]
    tmap = jax.tree_util.tree_map
    stats = []
    for it in range(max_iters):
        lane_act = np.asarray(active.any(axis=1))
        n_lanes = int(lane_act.sum())
        if n_lanes == 0:
            if until_empty:
                break
            continue    # every phase masks on active: a no-op step
        t0 = time.perf_counter()
        n_act = int(jnp.sum(active)) if collect_stats else 0
        if n_lanes == B:
            W = B
            states, active = step_for_width(B)(states, active,
                                               jnp.int32(it))
        else:
            # lane compaction: converged lanes drop out of the batch
            # instead of riding along as frozen flops
            idx, W = _compact_lane_index(lane_act)
            if obs.enabled():
                obs.event("lane_compaction", engine=engine_name,
                          program=program, it=it, lanes_active=n_lanes,
                          width=W, batch=B)
            sub_states = tmap(lambda a: a[idx], states)
            sub_states, sub_active = step_for_width(W)(
                sub_states, active[idx], jnp.int32(it))
            states = tmap(lambda f, p: f.at[idx].set(p),
                          states, sub_states)
            active = active.at[idx].set(sub_active)
        jax.block_until_ready(active)
        wall = time.perf_counter() - t0
        if collect_stats:
            stats.append(obs.BatchIterStats(
                it=it, lanes_active=n_lanes, n_active=n_act, wall_s=wall))
            if obs.enabled():
                wire = (int(wire_bytes_fn(n_lanes))
                        if wire_bytes_fn is not None else None)
                extra = {} if wire is None else {"wire_bytes": wire}
                obs.event("batch_iter", engine=engine_name,
                          program=program, it=it, lanes_active=n_lanes,
                          n_active=n_act, width=W, wall_s=wall, **extra)
                obs.observe("engine.batch_step_wall_s", wall,
                            engine=engine_name, program=program or "?")
                obs.cost_sample("dc", n_act, wall, it=it, batched=True,
                                width=W, engine=engine_name,
                                program=program)
    return states, active, stats


class Engine:
    """Single-device PPM engine.

    mode: 'hybrid' (paper's GPOP), 'dc' (GPOP_DC), 'sc' (GPOP_SC).
    backend: kernel backend for the DC scatter/gather — a name from
    :mod:`repro.backend.registry` ('ref', 'pallas-interpret',
    'pallas-native'), a KernelBackend instance, or None to auto-select
    from the platform / REPRO_KERNEL_BACKEND.
    use_pallas: deprecated alias (True -> backend='pallas-interpret',
    False -> backend='ref').
    """

    def __init__(self, layout: Layout, program: VertexProgram,
                 mode: str = "hybrid", bw_ratio: float = 2.0,
                 backend: Union[str, "kregistry.KernelBackend", None] = None,
                 use_pallas: Optional[bool] = None):
        assert mode in ("hybrid", "dc", "sc")
        if use_pallas is not None:
            warnings.warn(
                "Engine(use_pallas=...) is deprecated; pass "
                "backend='pallas-interpret' / 'ref' instead",
                DeprecationWarning, stacklevel=2)
            if backend is None:
                backend = "pallas-interpret" if use_pallas else "ref"
        self.layout = layout
        self.program = program
        self.mode = mode
        self.cost = CostModel.from_layout(layout, bw_ratio=bw_ratio)
        L = layout
        self.k, self.q, self.n_pad = L.k, L.q, L.n_pad

        # device-resident static structure
        self.png_src = jnp.asarray(L.png_src)                  # [NM]
        self.png_part = jnp.asarray(
            (L.png_src.astype(np.int64) // L.q).clip(0, L.k - 1)
            .astype(np.int32))
        self.msg_slot = jnp.asarray(L.msg_slot)                # [NE]
        self.edge_dst = jnp.asarray(L.edge_dst)                # [NE]
        self.edge_w = (jnp.asarray(L.edge_w)
                       if L.edge_w is not None else None)
        self.tile_src_part = jnp.asarray(L.tile_src_part)
        self.csr_indptr = jnp.asarray(L.csr_indptr)
        self.csr_indices = jnp.asarray(L.csr_indices)
        self.csr_w = (jnp.asarray(L.csr_w)
                      if L.csr_w is not None else None)
        self.deg = jnp.asarray(L.deg.astype(np.int32))         # [n_pad]
        self.vert_part = jnp.asarray(
            (np.arange(L.n_pad, dtype=np.int64) // L.q).astype(np.int32))

        # per-partition reductions used by the host-side mode decision
        @jax.jit
        def _part_stats(active):
            a32 = active.astype(jnp.int32)
            counts = jax.ops.segment_sum(a32, self.vert_part,
                                         num_segments=L.k)
            ea = jax.ops.segment_sum(a32 * self.deg, self.vert_part,
                                     num_segments=L.k)
            return counts, ea
        self._part_stats = _part_stats

        # kernel construction goes through the backend registry; each of
        # gather/scatter may fall back to 'ref' on its own when the chosen
        # backend has no lowering for this (monoid, dtype, platform)
        kset = kregistry.make_kernels(layout, program.monoid,
                                      backend=backend)
        self.kernels = kset
        self.backend_names = kset.names
        self.use_pallas = kset.any_pallas          # introspection compat
        self._gather_kernel = kset.gather
        self._scatter_kernel = kset.scatter
        # SC-stream monoid fold + touched flags through registry kernel
        # 'fold' (the blocked Pallas fold by default — flat below
        # REPRO_FOLD_MAX_SEGMENTS, two-level above, both carrying the
        # layout's tuned fold_tile/fold_q; budgets are static per
        # compiled step, so the stream shape is known at trace time)
        self._fold = kset.fold
        # fused DC step (registry kernel 'fused_dc'): one Pallas call
        # replacing scatter -> slot gather -> gather fold, selected when
        # the backend provides it and REPRO_FUSED != 0; otherwise the
        # composed path below runs (silently — that *is* the fallback)
        from ..kernels.fused_step import fused_enabled
        self._fused = kset.fused if fused_enabled() else None
        if self._fused is not None:
            self._fused.apply_weight = (
                program.apply_weight
                if (program.apply_weight is not None
                    and self.edge_w is not None) else None)
        self._step_cache = {}                      # (bv, be) -> jitted step

    # ------------------------------------------------------------------
    def _step_fn(self, bv: int, be: int):
        """Jitted iteration for static SC budgets (bv, be), cached per
        instance (an lru_cache on the method would pin ``self`` — layout
        arrays included — for the process lifetime)."""
        fn = self._step_cache.get((bv, be))
        if fn is None:
            fn = self._build_step(bv, be)
            self._step_cache[(bv, be)] = fn
        return fn

    def _build_step(self, bv: int, be: int):
        """Build the jitted iteration for static SC budgets (bv, be)."""
        prog, L, mono = self.program, self.layout, self.program.monoid
        n_pad, k, q = self.n_pad, self.k, self.q
        ident = mono.identity

        def step(state, active, dc_mask, it):
            msgs = prog.scatter_fn(state)                     # [n_pad]
            msgs = msgs.astype(mono.dtype)
            msgs_p = jnp.concatenate([msgs, mono.identity_array((1,))])
            active_p = jnp.concatenate(
                [active, jnp.zeros((1,), jnp.bool_)])

            # ---- initFrontier (selective continuity) ----
            if prog.init_fn is not None:
                st2, keep = prog.init_fn(state, it)
                state = _tree_where(active, st2, state)
                keep = keep & active
            else:
                keep = jnp.zeros((n_pad,), jnp.bool_)

            # ---- DC stream (paper Alg. 2: values-only messages over the
            # pre-written dc_bin adjacency) ----
            if self._fused is not None:
                # fused lowering: the kernel gathers each edge's source
                # value from msgs_p itself and folds it straight into the
                # two-level sub-accumulators — the [NM] bin buffer and
                # the [NE] edge-value stream never materialize
                table_valid = jnp.concatenate(
                    [active & dc_mask[self.vert_part],
                     jnp.zeros((1,), jnp.bool_)])
                acc, touched = self._fused(msgs_p, table_valid)
            else:
                msg_data = self._scatter_kernel(
                    msgs, active & dc_mask[self.vert_part])
                dc_valid = (active_p[self.png_src]
                            & dc_mask[self.png_part])         # [NM]
                msg_data_p = jnp.concatenate(
                    [msg_data, mono.identity_array((1,))])
                dc_valid_p = jnp.concatenate(
                    [dc_valid, jnp.zeros((1,), jnp.bool_)])
                edge_vals = msg_data_p[self.msg_slot]         # [NE]
                edge_valid = dc_valid_p[self.msg_slot]
                if (prog.apply_weight is not None
                        and self.edge_w is not None):
                    edge_vals = prog.apply_weight(edge_vals, self.edge_w)
                    edge_vals = jnp.where(edge_valid, edge_vals, ident)
                acc, touched = self._gather_kernel(
                    edge_vals, edge_valid, dc_mask.astype(jnp.int32))
                acc = jnp.concatenate([acc, mono.identity_array((1,))])
                touched = jnp.concatenate(
                    [touched, jnp.zeros((1,), jnp.bool_)])

            # ---- SC stream (static budgets; absent when be == 0) ----
            if be > 0:
                sc_active = active & ~dc_mask[self.vert_part]
                ids = jnp.nonzero(sc_active, size=bv,
                                  fill_value=n_pad)[0]         # [bv]
                degs = jnp.where(ids < n_pad, self.deg[jnp.minimum(ids, n_pad - 1)], 0)
                cum = jnp.cumsum(degs)
                total = cum[-1]
                j = jnp.arange(be, dtype=jnp.int32)
                vi = jnp.searchsorted(cum, j, side="right")
                vi = jnp.minimum(vi, bv - 1)
                starts = cum - degs
                src_v = ids[vi]
                e_idx = (self.csr_indptr[jnp.minimum(src_v, n_pad)]
                         + (j - starts[vi]))
                valid = j < total
                e_idx = jnp.where(valid, e_idx, 0)
                dst = jnp.where(valid, self.csr_indices[e_idx],
                                n_pad).astype(jnp.int32)
                vals = msgs_p[jnp.minimum(src_v, n_pad)]
                if prog.apply_weight is not None and self.csr_w is not None:
                    vals = prog.apply_weight(vals, self.csr_w[e_idx])
                vals = jnp.where(valid, vals, ident)
                acc2, touched2 = self._fold(vals, valid, dst, n_pad + 1)
                acc = mono.combine(acc, acc2)
                touched = touched | touched2

            acc = acc[:n_pad]
            touched = touched[:n_pad]

            # ---- Gather apply ----
            st3, activated = prog.apply_fn(state, acc, touched, it)
            state = _tree_where(touched, st3, state)
            activated = activated & touched

            # ---- filterFrontier on the union frontier ----
            new_active = keep | activated
            if prog.filter_fn is not None:
                st4, fkeep = prog.filter_fn(state, it)
                state = _tree_where(new_active, st4, state)
                new_active = new_active & fkeep
            return state, new_active

        return jax.jit(step)

    # ------------------------------------------------------------------
    def run(self, state=None, frontier=None, max_iters: int = 10_000,
            until_empty: bool = True, collect_stats: bool = True, *,
            resume_from=None, touched=None):
        """Host-driven loop: per-iteration mode decision (paper Eq. 1).

        ``resume_from=``/``touched=`` is the incremental-recompute entry
        point for dynamic graphs: pass a *previously converged* state
        (from a run on the pre-delta layout) as ``resume_from`` and the
        delta-touched vertices (``DeltaBuffer.touched()``) as ``touched``,
        and the loop restarts from the old fixpoint with only the touched
        vertices on the initial frontier.

        Exactness contract: for a *min-monoid* program (BFS / SSSP / CC)
        after an **insertion-only** delta this converges to exactly the
        cold fixpoint of the new graph.  The old fixpoint satisfies every
        old edge, insertions can only *lower* the least fixpoint, so the
        old state is a pointwise upper bound whose only violated
        constraints start at touched vertices — relaxation from there
        repairs every consequence and, by the least-fixpoint uniqueness
        argument (see :mod:`repro.serve.cache`), lands bit-exactly on the
        cold answer.  After deletions values may need to *rise*, which
        monotone relaxation cannot do: run cold instead.  Non-min monoids
        (PageRank) resume via residuals — a warm init reaches the unique
        damping-contraction fixpoint in fewer sweeps (see
        :func:`repro.apps.pagerank.pagerank`'s ``pr0``)."""
        if resume_from is not None:
            if state is not None:
                raise ValueError("pass either state= or resume_from=, "
                                 "not both")
            if touched is None:
                raise ValueError("resume_from= needs touched= (the "
                                 "delta-touched initial frontier, or the "
                                 "DeltaBuffer itself)")
            # `touched` may be the DeltaBuffer itself (preferred: the
            # boolean mask cannot carry the insert/delete distinction the
            # exactness contract depends on).  Deletion deltas must NOT
            # quietly recompute from the old fixpoint: monotone
            # relaxation can only lower values, so the resumed run would
            # CONVERGE — to a wrong (stale-upper-bound) answer.
            from ..graph.delta import DeltaBuffer
            if isinstance(touched, DeltaBuffer):
                if touched.num_deletes:
                    raise ValueError(
                        "resume_from= is exact only for insertion-only "
                        f"deltas; this delta removes {touched.num_deletes}"
                        " edge(s) and deleted edges may require values to "
                        "rise, which monotone relaxation cannot do — run "
                        "cold (state=/frontier=) on the new layout "
                        "instead")
                touched = touched.touched()
            if self.program.monoid.name not in ("min", "max", "or",
                                                "min_with_payload"):
                raise ValueError(
                    "resume_from= requires an idempotent monoid (min/max/"
                    f"or): re-folding under {self.program.monoid.name!r} "
                    "double-counts contributions already absorbed into "
                    "the old fixpoint — PageRank-style programs resume "
                    "via the residual path (pagerank(pr0=)) instead")
            state, frontier = resume_from, touched
        if state is None or frontier is None:
            raise ValueError("run() needs state+frontier (or "
                             "resume_from=+touched=)")
        active = jnp.asarray(frontier, jnp.bool_)
        stats = []
        for it in range(max_iters):
            counts, ea = self._part_stats(active)
            counts = np.asarray(counts)
            ea = np.asarray(ea)
            n_active = int(counts.sum())
            if until_empty and n_active == 0:
                break
            has_active = counts > 0
            if self.mode == "dc":
                dc_mask = has_active
            elif self.mode == "sc":
                dc_mask = np.zeros(self.k, bool)
            else:
                dc_mask = self.cost.choose_dc(ea, has_active)
            sc_sel = (~dc_mask) & has_active
            bv = _next_pow2(int(counts[sc_sel].sum())) if sc_sel.any() else 0
            be = _next_pow2(int(ea[sc_sel].sum())) if sc_sel.any() else 0
            if sc_sel.any() and be == 0:
                be, bv = 1, max(bv, 1)      # active vertices with degree 0
            t0 = time.perf_counter()
            state, active = self._step_fn(bv, be)(
                state, active, jnp.asarray(dc_mask), jnp.int32(it))
            jax.block_until_ready(active)
            if collect_stats:
                b = self.cost.bytes_for(dc_mask, ea, has_active)
                dc_p, sc_p = int(dc_mask.sum()), int(sc_sel.sum())
                mode_str = ("dc" if sc_p == 0 else
                            "sc" if dc_p == 0 else "hybrid")
                st = obs.IterStats(
                    it=it, n_active=n_active, e_active=int(ea.sum()),
                    dc_parts=dc_p, sc_parts=sc_p,
                    dc_bytes=b["dc_bytes"], sc_bytes=b["sc_bytes"],
                    wall_s=time.perf_counter() - t0,
                    mode=mode_str, program=self.program.name)
                stats.append(st)
                # dc_e/sc_e split the active-edge count by stream: pure
                # dc/sc steps give the online Eq. 1 calibration clean
                # single-mode (size, time) points
                obs.record_engine_iter(
                    "core", st,
                    dc_e=int(ea[dc_mask].sum()), sc_e=int(ea[sc_sel].sum()))
        return state, active, stats

    # ------------------------------------------------------------------
    def _batched_step_fn(self, B: int):
        """Jitted batched iteration: the DC step vmapped over a leading
        query axis, cached per batch size (shapes are static per B)."""
        key = ("batched", B)
        fn = self._step_cache.get(key)
        if fn is not None:
            return fn
        step = self._step_fn(0, 0)        # DC-only step (no SC budgets)
        k, q = self.k, self.q

        def one(state, active, it):
            # per-lane gPartList: partitions with >=1 active vertex run DC,
            # empty partitions are excluded entirely (same decision `run`
            # makes in mode='dc', but computed in-graph so it can vmap)
            counts = active.astype(jnp.int32).reshape(k, q).sum(axis=1)
            return step(state, active, counts > 0, it)

        def batched(states, active, it):
            done = ~active.any(axis=1)                         # [B]
            new_states, new_active = jax.vmap(
                one, in_axes=(0, 0, None))(states, active, it)
            # freeze converged lanes: an empty frontier is already a
            # no-op for every phase (all updates are masked on active /
            # touched), but the explicit freeze makes the contract
            # independent of the program's init/filter behaviour
            keep = ~done
            new_states = _tree_where(keep, new_states, states)
            new_active = new_active & keep[:, None]
            return new_states, new_active

        fn = jax.jit(batched)
        self._step_cache[key] = fn
        return fn

    def run_batched(self, states, frontiers, max_iters: int = 10_000,
                    until_empty: bool = True, collect_stats: bool = True):
        """Batched multi-source execution: B independent queries of the
        same vertex program advance together through one vmapped DC
        iteration per superstep.

        ``states`` is a pytree whose leaves carry a leading query axis
        ``[B, ...]``; ``frontiers`` is ``[B, n_pad]`` bool.  Every kernel
        launch (scatter / gather / fold) is amortized across the batch —
        the serving-tier analogue of the paper's §5 repeated-query
        argument: the O(E) layout is resident and shared, only the O(V)
        per-query state is replicated.  The *union* frontier drives
        convergence (the loop runs until every lane drained); per-query
        done masks freeze converged lanes inside a step, and between
        steps converged lanes are compacted out of the batch entirely
        (packed to the next power-of-two width, so at most log2(B)
        distinct step shapes ever compile).  Results are bit-exact with
        B sequential :meth:`run` calls in mode='dc'.
        """
        active = jnp.asarray(frontiers, jnp.bool_)
        assert active.ndim == 2, "frontiers must be [B, n_pad]"
        states = jax.tree_util.tree_map(jnp.asarray, states)
        return _run_batched_loop(self._batched_step_fn, states, active,
                                 max_iters, until_empty, collect_stats,
                                 engine_name="core",
                                 program=self.program.name)

    # ------------------------------------------------------------------
    def run_fused(self, state, frontier, iters: int):
        """Fully-jitted fixed-iteration loop (DC mode, no host round trips).

        This is the PageRank-style path: all partitions scatter DC every
        iteration (paper §6.2.2: "PageRank always uses DC mode").
        """
        step = self._step_fn(0, 0)
        dc_mask = jnp.ones((self.k,), jnp.bool_)

        @jax.jit
        def loop(state, active):
            def body(it, carry):
                st, act = carry
                return step(st, act, dc_mask, it)
            return jax.lax.fori_loop(0, iters, body, (state, active))

        if not obs.enabled():
            return loop(state, jnp.asarray(frontier, jnp.bool_))
        t0 = time.perf_counter()
        out = loop(state, jnp.asarray(frontier, jnp.bool_))
        jax.block_until_ready(out)
        obs.event("fused_run", engine="core", program=self.program.name,
                  iters=iters, wall_s=time.perf_counter() - t0)
        return out
