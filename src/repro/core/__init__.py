from . import monoid
from .cost import CostModel
from .engine import Engine, IterStats
from .program import VertexProgram

__all__ = ["monoid", "CostModel", "Engine", "IterStats", "VertexProgram"]
