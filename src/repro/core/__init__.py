from . import monoid
from .cost import CostModel
from .engine import Engine
# IterStats now lives in the obs schema; re-exported here (silently) for
# the public repro.core surface.  repro.core.engine.IterStats still
# resolves but emits a DeprecationWarning.
from ..obs.schema import IterStats
from .program import VertexProgram

__all__ = ["monoid", "CostModel", "Engine", "IterStats", "VertexProgram"]
