"""Backward-compat shim: the distributed PPM engine moved to
``repro.dist.engine`` (the home of all multi-device machinery)."""
from ..dist.engine import (DistEngine, build_dc_step, build_hybrid_step,
                           build_sc_step)

__all__ = ["DistEngine", "build_dc_step", "build_sc_step",
           "build_hybrid_step"]
