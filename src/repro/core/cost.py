"""Eq. 1 dual-mode communication cost model (paper §3.3).

A partition is scattered destination-centric iff

    (E^p((r+1)d_i + 2r d_v) + k d_i) / BW_DC
        <=  (2r E_a^p d_v + 3 E_a^p d_i) / BW_SC

The DC side is a per-partition constant; the SC side is linear in the active
edges E_a^p.  ``BW_DC / BW_SC`` is a user-configurable ratio, default 2 as in
the paper.  On the TPU mapping, DC traffic is dense contiguous all_to_all +
streamed static adjacency, SC traffic is ragged (value, id) pairs — the same
two expressions price both (see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class CostModel:
    dc_cost: np.ndarray       # float64[k] bytes, per-partition constant
    sc_coeff: np.ndarray      # float64[k] bytes per active edge
    bw_ratio: float = 2.0     # BW_DC / BW_SC

    @classmethod
    def from_layout(cls, layout, d_i: int = 4, d_v: int = 4,
                    bw_ratio: float = 2.0) -> "CostModel":
        return cls(dc_cost=layout.dc_cost_bytes(d_i, d_v).astype(np.float64),
                   sc_coeff=layout.sc_cost_coeff(d_i, d_v),
                   bw_ratio=bw_ratio)

    def choose_dc(self, active_edges: np.ndarray,
                  has_active: np.ndarray) -> np.ndarray:
        """Per-partition mode decision. True -> DC. Inactive partitions are
        excluded from both modes by the 2-level active list (gPartList)."""
        sc_cost = active_edges.astype(np.float64) * self.sc_coeff
        return (self.dc_cost <= self.bw_ratio * sc_cost) & has_active

    def bytes_for(self, dc_mask: np.ndarray, active_edges: np.ndarray,
                  has_active: np.ndarray) -> dict:
        dc = float(self.dc_cost[dc_mask & has_active].sum())
        sc_sel = (~dc_mask) & has_active
        sc = float((active_edges * self.sc_coeff)[sc_sel].sum())
        return {"dc_bytes": dc, "sc_bytes": sc, "total_bytes": dc + sc}
