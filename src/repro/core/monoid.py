"""Gather-phase combine monoids.

The paper's ``gatherFunc`` is arbitrary sequential code executed under
exclusive partition ownership.  On TPU the fold must be an associative and
commutative monoid so it can be evaluated as a data-parallel segmented
reduction; all five applications evaluated in the paper (BFS, PageRank,
Label Propagation, SSSP, Nibble) use such monoids (min / add / first-visit).

``min_with_payload`` packs a (key, payload) pair into a single uint64 lattice
so that e.g. SSSP can keep distance *and* parent inside a pure ``min`` fold
(non-negative float32 keys have monotone bit patterns).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Monoid:
    name: str
    dtype: np.dtype
    identity: object                      # scalar identity element
    combine: Callable                     # (a, b) -> a*b  (assoc. + comm.)
    segment_fold: Callable                # (vals, ids, num_segments) -> acc

    def identity_array(self, shape):
        return jnp.full(shape, self.identity, dtype=self.dtype)


def _seg(fn):
    def fold(vals, ids, num_segments):
        return fn(vals, ids, num_segments=num_segments,
                  indices_are_sorted=False)
    return fold


def add(dtype=jnp.float32) -> Monoid:
    return Monoid("add", jnp.dtype(dtype), np.array(0, dtype),
                  lambda a, b: a + b, _seg(jax.ops.segment_sum))


def min_(dtype=jnp.uint32) -> Monoid:
    ident = (np.array(np.inf, dtype) if jnp.issubdtype(dtype, jnp.floating)
             else np.array(np.iinfo(dtype).max, dtype))
    return Monoid("min", jnp.dtype(dtype), ident,
                  jnp.minimum, _seg(jax.ops.segment_min))


def max_(dtype=jnp.uint32) -> Monoid:
    ident = (np.array(-np.inf, dtype) if jnp.issubdtype(dtype, jnp.floating)
             else np.array(np.iinfo(dtype).min, dtype))
    return Monoid("max", jnp.dtype(dtype), ident,
                  jnp.maximum, _seg(jax.ops.segment_max))


def or_() -> Monoid:
    return Monoid("or", jnp.dtype(jnp.uint32), np.uint32(0),
                  lambda a, b: a | b, _seg(jax.ops.segment_max))


def min_with_payload() -> Monoid:
    """min over packed uint64 = (f32-key bits << 32) | uint32 payload.

    Requires x64 (``jax.experimental.enable_x64()`` or JAX_ENABLE_X64);
    without it JAX silently truncates uint64 to uint32."""
    return Monoid("min_with_payload", jnp.dtype(jnp.uint64),
                  np.uint64(np.iinfo(np.uint64).max),
                  jnp.minimum, _seg(jax.ops.segment_min))


def pack_key_payload(key_f32, payload_u32):
    bits = jax.lax.bitcast_convert_type(key_f32.astype(jnp.float32),
                                        jnp.uint32)
    return (bits.astype(jnp.uint64) << np.uint64(32)) | \
        payload_u32.astype(jnp.uint64)


def unpack_key_payload(packed_u64):
    key = jax.lax.bitcast_convert_type(
        (packed_u64 >> np.uint64(32)).astype(jnp.uint32), jnp.float32)
    payload = (packed_u64 & np.uint64(0xFFFFFFFF)).astype(jnp.uint32)
    return key, payload


REGISTRY = {
    "add": add, "min": min_, "max": max_, "or": or_,
    "min_with_payload": min_with_payload,
}
