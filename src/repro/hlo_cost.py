"""HLO-text cost walker: FLOPs / HBM bytes / collective wire bytes with
while-loop trip-count scaling.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE (verified
empirically: a 10-iteration scan of a matmul reports the FLOPs of a single
matmul).  Our models scan over layers / chunks / microbatches, so everything
interesting lives inside loops.  This walker parses ``compiled.as_text()``,
computes per-computation costs, and multiplies loop bodies by their trip
counts (parsed from the loop-condition's scalar constant — lax.scan/fori
lower to ``compare(i, constant(N)), direction=LT``).

Cost conventions (documented for the roofline):
  * dot: 2 x prod(result dims) x prod(contracting dims) FLOPs;
    bytes = operands + result.
  * fusion: bytes = boundary operands + result (internal reuse is free —
    matches the TPU VMEM model); FLOPs = dots inside + 1/elem for the
    fused elementwise body.
  * collectives: wire bytes with ring-algorithm factors
    (ag/rs/a2a: (N-1)/N, ar: 2(N-1)/N, cp: 1), N = replica-group size.
  * gather/scatter count full operand bytes (upper bound, same as XLA).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\)|[\w\[\],{}\d]+))\s+"
    r"([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"=\s*[su]32\[\]\s+constant\((\d+)\)")
_LCD_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,\s]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_STRUCTURAL = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "get-dimension-size",
    "all-gather-done", "all-reduce-done", "collective-permute-done",
    "async-done", "async-update", "opt-barrier",
}
_COLLECTIVES = {
    "all-reduce": "ar", "all-gather": "ag", "reduce-scatter": "rs",
    "all-to-all": "a2a", "collective-permute": "cp",
    "all-reduce-start": "ar", "all-gather-start": "ag",
    "collective-permute-start": "cp", "ragged-all-to-all": "a2a",
}


def _shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((m.group(1), dims))
    return out


def _bytes_of(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _elems_of(type_str: str) -> int:
    total = 0
    for _, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    wire: float = 0.0
    coll_counts: Optional[dict] = None

    def __add__(self, o):
        cc = dict(self.coll_counts or {})
        for k, v in (o.coll_counts or {}).items():
            cc[k] = cc.get(k, 0) + v
        return Cost(self.flops + o.flops, self.bytes + o.bytes,
                    self.wire + o.wire, cc)

    def scaled(self, k: float):
        cc = {kk: v * k for kk, v in (self.coll_counts or {}).items()}
        return Cost(self.flops * k, self.bytes * k, self.wire * k, cc)


class HloCostModel:
    def __init__(self, hlo_text: str, default_group: int = 1):
        self.default_group = default_group
        self.computations: Dict[str, List[str]] = {}
        self.entry: Optional[str] = None
        self.types: Dict[str, str] = {}
        self._parse(hlo_text)
        self._memo: Dict[str, Cost] = {}
        self._param_util: Dict[str, dict] = {}

    # ------------------------------------------------------------------
    def _parse(self, text: str):
        cur = None
        for line in text.splitlines():
            if cur is None:
                m = _COMP_HDR.match(line.strip())
                if m and line.rstrip().endswith("{"):
                    cur = m.group(2)
                    self.computations[cur] = []
                    if m.group(1):
                        self.entry = cur
                continue
            if line.startswith("}"):
                cur = None
                continue
            self.computations[cur].append(line)
            om = _OP_RE.match(line)
            if om:
                self.types[om.group(1)] = om.group(2)

    # ------------------------------------------------------------------
    def _trip_count(self, cond: str) -> int:
        best = 1
        for line in self.computations.get(cond, []):
            for m in _CONST_RE.finditer(line):
                best = max(best, int(m.group(1)))
        return best

    def _group_size(self, line: str) -> int:
        m = _GROUPS_BRACE_RE.search(line)
        if m:
            return len(m.group(1).split(","))
        m = _GROUPS_IOTA_RE.search(line)
        if m:
            return int(m.group(2))
        return self.default_group

    # ------------------------------------------------------------------
    def cost(self, comp: Optional[str] = None) -> Cost:
        comp = comp or self.entry
        if comp in self._memo:
            return self._memo[comp]
        self._memo[comp] = Cost()          # cycle guard
        total = Cost(coll_counts={})
        for line in self.computations.get(comp, []):
            om = _OP_RE.match(line)
            if not om:
                continue
            name, type_str, op = om.groups()
            if op in _STRUCTURAL:
                continue
            if op == "while":
                cm = _CALLS_RE.search(line)
                dm = _COND_RE.search(line)
                trip = self._trip_count(dm.group(1)) if dm else 1
                if cm:
                    total = total + self.cost(cm.group(1)).scaled(trip)
                continue
            if op == "conditional":
                bm = _BRANCHES_RE.search(line)
                if bm:
                    branches = [_s.strip().lstrip("%")
                                for _s in bm.group(1).split(",")]
                    costs = [self.cost(b) for b in branches]
                    best = max(costs, key=lambda c: max(c.flops, c.bytes))
                    total = total + best
                continue
            if op in ("call", "custom-call", "fusion", "map", "reduce",
                      "reduce-window", "sort", "scatter", "select-and-scatter"):
                b = self._boundary_bytes(line, type_str)
                total = total + Cost(bytes=b)
                cm = _CALLS_RE.search(line)
                if cm and cm.group(1) in self.computations:
                    inner = self.cost(cm.group(1))
                    # fusion boundary bytes already counted; take only
                    # flops + wire from inside
                    total = total + Cost(flops=inner.flops,
                                         wire=inner.wire,
                                         coll_counts=inner.coll_counts)
                elif op == "fusion":
                    total = total + Cost(flops=_elems_of(type_str))
                continue
            if op in _COLLECTIVES:
                b_out = _bytes_of(type_str)
                n = max(self._group_size(line), 1)
                kind = _COLLECTIVES[op]
                if kind == "ar":
                    w = 2.0 * b_out * (n - 1) / n
                elif kind == "ag":
                    w = b_out * (n - 1) / n        # output-size based
                elif kind == "rs":
                    # rs result is 1/n of the reduced input: wire ~ in*(n-1)/n
                    w = b_out * (n - 1)
                elif kind == "a2a":
                    # a2a result size == operand size; (n-1)/n leaves the chip
                    w = b_out * (n - 1) / n
                else:
                    w = b_out
                total = total + Cost(bytes=2 * b_out, wire=w,
                                     coll_counts={op: 1})
                continue
            if op == "dot":
                res_elems = _elems_of(type_str)
                ops_ = _OPERAND_RE.findall(line.split("(", 1)[1])
                k = 1
                lm = _LCD_RE.search(line)
                if ops_ and lm is not None:
                    lhs_t = self.types.get(ops_[0], "")
                    dims = _shape_dims(lhs_t)
                    if dims:
                        shape = dims[0][1]
                        for ci in [int(x) for x in lm.group(1).split(",")
                                   if x]:
                            if ci < len(shape):
                                k *= shape[ci]
                b = self._boundary_bytes(line, type_str)
                total = total + Cost(flops=2.0 * res_elems * k, bytes=b)
                continue
            # generic op: elementwise-ish
            b = self._boundary_bytes(line, type_str)
            total = total + Cost(flops=_elems_of(type_str), bytes=b)
        self._memo[comp] = total
        return total

    def _boundary_bytes(self, line: str, type_str: str) -> float:
        b = _bytes_of(type_str)
        args = line.split("(", 1)[1]
        # cut attribute tail: operands come before the first "),"
        args = args.split(")", 1)[0]
        cm = _CALLS_RE.search(line)
        util = (self._fusion_param_bytes(cm.group(1))
                if (cm and "fusion" in line) else None)
        for i, opn in enumerate(_OPERAND_RE.findall(args)):
            full = _bytes_of(self.types.get(opn, ""))
            if util is not None and i in util:
                full = min(full, util[i])
            b += full
        return float(b)

    def _fusion_param_bytes(self, comp: str) -> dict:
        """Operand utilization for fusions (the XLA cost-analysis rule):
        a parameter consumed only through dynamic-slice/gather inside the
        fused computation is charged its slice size, not the full array —
        otherwise scan-residual stacks ([L, ...]) would be charged L x per
        layer step (observed 30x memory overcount on deep models)."""
        if comp in self._param_util:
            return self._param_util[comp]
        out: dict = {}
        lines = self.computations.get(comp, [])
        # param name -> index
        pidx = {}
        for line in lines:
            om = _OP_RE.match(line)
            if om and om.group(3) == "parameter":
                m = re.search(r"parameter\((\d+)\)", line)
                if m:
                    pidx[om.group(1)] = int(m.group(1))
        sliced: dict = {}
        direct: set = set()
        for line in lines:
            om = _OP_RE.match(line)
            if not om:
                continue
            name, t, op = om.groups()
            if op == "parameter":
                continue
            args = line.split("(", 1)[1].split(")", 1)[0]
            ops_ = _OPERAND_RE.findall(args)
            for j, o in enumerate(ops_):
                if o not in pidx:
                    continue
                if op in ("dynamic-slice", "gather") and j == 0:
                    sliced[pidx[o]] = sliced.get(pidx[o], 0) + _bytes_of(t)
                else:
                    direct.add(pidx[o])
        out = {i: b for i, b in sliced.items() if i not in direct}
        self._param_util[comp] = out
        return out


def analyze(hlo_text: str, default_group: int = 1) -> dict:
    cm = HloCostModel(hlo_text, default_group)
    c = cm.cost()
    return dict(flops=c.flops, bytes=c.bytes, wire_bytes=c.wire,
                coll_counts=c.coll_counts or {})
