"""Kernel/phase tracing: named scopes for jit traces and on-demand
profiler captures.

``kernel_scope`` is what the kernel wrappers in :mod:`repro.kernels.ops`
enter around their bodies: under an active ``jax.profiler.trace()``
capture (or any XLA dump) the scatter / gather / fold phases then show up
as named regions instead of anonymous fusions.  ``jax.named_scope`` adds
trace-time metadata only — no ops, no retraces, zero runtime cost — and
is skipped entirely when telemetry is disabled.

``annotation`` is the host-side counterpart (``TraceAnnotation``): wrap a
host region (a scheduler tick, a drain) so it is attributable in the
same profile.
"""
from __future__ import annotations

import contextlib

import jax

from . import metrics

_NULL = contextlib.nullcontext()


def kernel_scope(name: str):
    """``jax.named_scope(name)`` when telemetry is enabled, else a
    no-op context.  Safe inside jit traces and shard_map bodies."""
    if not metrics.enabled():
        return _NULL
    return jax.named_scope(name)


def annotation(name: str):
    """Host-side profiler annotation (TraceAnnotation) when enabled."""
    if not metrics.enabled():
        return _NULL
    try:
        return jax.profiler.TraceAnnotation(name)
    except Exception:                         # profiler unavailable
        return _NULL


@contextlib.contextmanager
def trace(path):
    """Capture a profiled region into ``path`` (TensorBoard/XPlane trace
    directory) — wrap one engine iteration to attribute its kernels:

        with obs.trace("/tmp/ppm-trace"):
            engine.run(state, frontier, max_iters=1, until_empty=False)

    Runs regardless of ``REPRO_OBS`` — an explicit capture request.
    """
    jax.profiler.start_trace(str(path))
    try:
        yield
    finally:
        jax.profiler.stop_trace()
