"""Exporters: JSONL event files and Prometheus text snapshots.

Two consumption models, matching how the telemetry is actually read:

* **JSONL events** — one JSON object per line, schema'd by
  :mod:`repro.obs.schema`.  ``write_jsonl`` dumps a registry's buffered
  events; :class:`JsonlSink` streams records as they are produced (what
  the benchmarks use for their per-row ``telemetry`` sidecars, and what
  ``REPRO_OBS_SINK`` wires the default registry to).
* **Prometheus text** — ``prometheus_text`` renders a point-in-time
  snapshot of every counter / gauge / histogram in the exposition
  format, so a scrape endpoint (or a human) can read the serving tier's
  queue depth, hit ratios, and latency percentiles directly.
"""
from __future__ import annotations

import json
import re
from pathlib import Path

from . import metrics as metrics_lib
from .metrics import _json_default

_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")
_LABEL_RE = re.compile(r"[^a-zA-Z0-9_]")


def _prom_name(name: str) -> str:
    return "repro_" + _NAME_RE.sub("_", name)


def _prom_labels(labels: dict) -> str:
    if not labels:
        return ""
    items = sorted(labels.items())
    body = ",".join(f'{_LABEL_RE.sub("_", str(k))}="{v}"'
                    for k, v in items)
    return "{" + body + "}"


# ----------------------------------------------------------------------
# JSONL
# ----------------------------------------------------------------------

class JsonlSink:
    """Streaming JSONL writer (context manager)."""

    def __init__(self, path):
        self.path = Path(path)
        self._f = None

    def __enter__(self):
        self._f = open(self.path, "a", encoding="utf-8")
        return self

    def emit(self, rec: dict):
        if self._f is None:
            self._f = open(self.path, "a", encoding="utf-8")
        self._f.write(json.dumps(rec, default=_json_default) + "\n")

    def close(self):
        if self._f is not None:
            self._f.close()
            self._f = None

    def __exit__(self, *exc):
        self.close()
        return False


def write_jsonl(path, registry=None) -> int:
    """Dump a registry's buffered events to ``path``; returns the count."""
    registry = registry or metrics_lib.registry()
    evs = registry.events()
    with JsonlSink(path) as sink:
        for e in evs:
            sink.emit(e)
    return len(evs)


def read_jsonl(path):
    """Parse a JSONL event file back into a list of dicts."""
    out = []
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


# ----------------------------------------------------------------------
# Prometheus text exposition
# ----------------------------------------------------------------------

def prometheus_text(registry=None) -> str:
    """Snapshot every metric in the Prometheus text format (0.0.4)."""
    registry = registry or metrics_lib.registry()
    by_name = {}                  # (kind, name) -> [metric, ...]
    for (kind, name, _), m in sorted(registry.metrics().items()):
        by_name.setdefault((kind, name), []).append(m)
    lines = []
    for (kind, name), ms in by_name.items():
        pname = _prom_name(name)
        lines.append(f"# TYPE {pname} "
                     f"{'histogram' if kind == 'histogram' else kind}")
        for m in ms:
            lab = m.labels
            if kind in ("counter", "gauge"):
                lines.append(f"{pname}{_prom_labels(lab)} {m.value}")
                continue
            cum = 0
            for ub, c in m.cumulative_buckets():
                cum = c
                le = dict(lab, le=f"{ub:.6g}")
                lines.append(f"{pname}_bucket{_prom_labels(le)} {c}")
            inf = dict(lab, le="+Inf")
            lines.append(f"{pname}_bucket{_prom_labels(inf)} {max(cum, m.n)}")
            lines.append(f"{pname}_sum{_prom_labels(lab)} {m.sum:.9g}")
            lines.append(f"{pname}_count{_prom_labels(lab)} {m.n}")
    return "\n".join(lines) + ("\n" if lines else "")
