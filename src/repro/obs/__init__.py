"""repro.obs — unified telemetry for engines, kernels, and the serving
tier.

GPOP's efficiency claims are *measured* claims: the Eq. 1 hybrid mode
decision and the paper's traffic tables exist because the runtime knows
per-partition active counts, degrees, and communication volumes every
iteration.  This package is where those signals live instead of dying at
the call site: a dependency-free metrics registry (counters, gauges,
log-bucketed histograms with p50/p95/p99), a schema'd JSONL event
stream, per-step cost samples for online Eq. 1 calibration, kernel
named-scope tracing, and Prometheus/JSONL exporters.

Environment knobs
-----------------

``REPRO_OBS``
    Master switch.  Unset or truthy -> telemetry ON (the default: the
    recording paths are host-side appends on data the engines already
    hold, never extra device syncs).  ``REPRO_OBS=0`` (also ``false`` /
    ``off`` / ``no``) disables every recording entry point behind a
    single attribute test — no metric objects are created, no events are
    buffered, traced computations are unchanged (no retraces), and the
    measured wall overhead on the serving benchmark is <1%.
    ``set_enabled()`` / ``override_enabled()`` flip it at runtime.

``REPRO_OBS_SINK``
    Optional path.  When set, every event the default registry records
    is also streamed to this file as one JSON line (append mode,
    flushed per event) — the artifact ``tools/check_obs_schema.py``
    validates and ``tools/obs_report.py`` renders.

What gets recorded
------------------

* **Engines** — ``Engine.run`` / ``run_batched`` / ``run_fused`` and
  ``DistEngine.run`` / ``run_batched`` emit per-iteration events
  (mode decision, dc/sc partition counts, active vertex/edge counts,
  modeled or analytic wire bytes, step wall time), step-wall
  histograms keyed by mode, lane-compaction events on the batched
  paths, and ``(mode, active-edge count, wall seconds)``
  **cost samples** — read them back with :func:`cost_samples`; they are
  exactly the table an online Eq. 1 calibration fits.
* **Kernels** — every registry-constructed scatter/gather/fold/spmv
  call runs under a ``jax.named_scope`` tagged with the kernel and
  backend name, so a ``jax.profiler.trace()`` capture (see
  :func:`trace`) attributes device time to PPM phases.
* **Serving tier** — ``GraphQueryServer`` and the LM ``Server`` record
  queue depth, fused-batch/drain sizes, LRU hit/miss counters (labeled
  by layout identity, so hit rates never aggregate across incompatible
  layouts), and end-to-end query latency histograms.

Quick use::

    from repro import obs
    obs.reset()
    bfs(layout, source=0)
    for mode, size, wall in obs.cost_samples():
        ...                                   # Eq. 1 calibration input
    print(obs.export.prometheus_text())
    obs.export.write_jsonl("events.jsonl")
"""
from __future__ import annotations

from . import export, schema, tracing
from .metrics import (Counter, Gauge, Histogram, Registry, cost_sample,
                      cost_samples, counter, enabled, event, events, gauge,
                      histogram, inc, observe, override_enabled, registry,
                      reset, set_enabled, set_gauge, snapshot)
from .schema import BatchIterStats, EVENT_SCHEMA, IterStats, validate_event
from .tracing import annotation, kernel_scope, trace

__all__ = [
    "export", "schema", "tracing",
    "Counter", "Gauge", "Histogram", "Registry",
    "cost_sample", "cost_samples", "counter", "enabled", "event",
    "events", "gauge", "histogram", "inc", "observe", "override_enabled",
    "registry", "reset", "set_enabled", "set_gauge", "snapshot",
    "BatchIterStats", "EVENT_SCHEMA", "IterStats", "validate_event",
    "annotation", "kernel_scope", "trace",
    "record_engine_iter",
]


def record_engine_iter(engine: str, st: IterStats, wire_bytes=None,
                       **extra):
    """Record one engine iteration: JSONL event + step-wall histogram +
    Eq. 1 cost sample.  A no-op when telemetry is disabled; every value
    is host-resident already (no device syncs)."""
    if not enabled():
        return
    d = schema.as_event(st)
    if wire_bytes is not None:
        d["wire_bytes"] = int(wire_bytes)
    d.update(extra)
    event("engine_iter", engine=engine, **d)
    observe("engine.step_wall_s", st.wall_s, engine=engine,
            program=st.program or "?", mode=st.mode or "?")
    cost_sample(st.mode or "?", st.e_active, st.wall_s, it=st.it,
                engine=engine, program=st.program)
