"""Telemetry schema: the per-iteration stat records and the JSONL event
contract.

``IterStats`` / ``BatchIterStats`` are the engine-facing per-iteration
records (they lived in :mod:`repro.core.engine` before the obs layer
existed; the engine re-exports them as a compat shim, so every existing
``res["stats"][i].dc_bytes`` consumer keeps working).  ``as_event``
turns one into the dict the JSONL sink ships.

``EVENT_SCHEMA`` is the machine-checkable contract for every event type
the repo emits: per event, the required fields and their types.  Extra
fields are always allowed (events are forward-extensible); missing or
mistyped required fields are a schema violation.
``tools/obs_schema.json`` is the checked-in serialization of this dict
(``tools/check_obs_schema.py`` validates exported JSONL against it
without importing the repo; a test asserts the two never diverge).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class IterStats:
    """Per-iteration record of an :meth:`Engine.run` invocation."""
    it: int
    n_active: int
    e_active: int
    dc_parts: int
    sc_parts: int
    dc_bytes: float
    sc_bytes: float
    wall_s: float
    #: effective step mode ('dc' / 'sc' / 'hybrid'); optional for
    #: backward compatibility with pre-obs constructors
    mode: str = ""
    #: vertex-program name, for grouping a multi-app run's telemetry
    program: str = ""


@dataclasses.dataclass
class BatchIterStats:
    """Per-iteration stats of a :meth:`Engine.run_batched` invocation."""
    it: int
    lanes_active: int         # queries still converging this iteration
    n_active: int             # active vertices summed over all lanes
    wall_s: float


def as_event(stats) -> dict:
    return dataclasses.asdict(stats)


# ----------------------------------------------------------------------
# event contract
# ----------------------------------------------------------------------

#: every event implicitly carries {"event": str, "ts": float}
EVENT_SCHEMA = {
    "version": 1,
    "events": {
        # one engine iteration (single-device or distributed); dist steps
        # add wire_bytes (analytic all_to_all payload)
        "engine_iter": {
            "required": {"engine": "str", "program": "str", "it": "int",
                         "mode": "str", "n_active": "int",
                         "e_active": "int", "wall_s": "float"},
        },
        # one batched (multi-source) engine step
        "batch_iter": {
            "required": {"engine": "str", "program": "str", "it": "int",
                         "lanes_active": "int", "width": "int",
                         "wall_s": "float"},
        },
        # converged lanes compacted out of a batch (pow2 repack)
        "lane_compaction": {
            "required": {"engine": "str", "program": "str", "it": "int",
                         "lanes_active": "int", "width": "int",
                         "batch": "int"},
        },
        # a fully-jitted fixed-iteration loop (Engine.run_fused)
        "fused_run": {
            "required": {"engine": "str", "program": "str", "iters": "int",
                         "wall_s": "float"},
        },
        # one fused serve-tier batch answered by run_batched
        "serve_batch": {
            "required": {"app": "str", "layout": "str", "batch": "int",
                         "distinct_sources": "int", "width": "int",
                         "wall_s": "float"},
        },
        # one query answered on the single-query path
        "serve_query": {
            "required": {"app": "str", "layout": "str", "cached": "bool",
                         "wall_s": "float"},
        },
        # a fused batch that ran with landmark-seeded initial state
        # (semantic cache hit on at least one lane); saved_iters is the
        # landmark's cold iteration count minus the seeded run's, floored
        # at zero — a proxy for the iterations the seed saved
        "seeded_batch": {
            "required": {"app": "str", "layout": "str", "batch": "int",
                         "seeded": "int", "iters": "int",
                         "saved_iters": "int"},
        },
        # one landmark precomputed by the async cache warmer
        "cache_warm": {
            "required": {"app": "str", "layout": "str", "source": "int",
                         "wall_s": "float"},
        },
        # result/semantic cache dropped (same-layout invalidation escape
        # hatch)
        "cache_clear": {
            "required": {"layout": "str"},
        },
        # server re-pointed at a new resident layout
        "layout_swap": {
            "required": {"old": "str", "new": "str"},
        },
        # apply_delta relayouted a graph delta (dirty partitions only)
        "delta_apply": {
            "required": {"dirty_parts": "int", "k": "int",
                         "inserts": "int", "deletes": "int",
                         "wall_s": "float"},
        },
        # an epoch-tagged layout swap: scoped invalidation accounting
        # (changed_parts = partitions whose content tag changed; evicted /
        # migrated = old-tag cache entries dropped / re-keyed)
        "epoch_swap": {
            "required": {"old": "str", "new": "str", "epoch": "int",
                         "delta": "bool", "changed_parts": "int",
                         "evicted": "int", "migrated": "int"},
        },
        # one benchmark row (per-row timings from benchmarks/*)
        "bench_row": {
            "required": {"kernel": "str", "backend": "str",
                         "wall_s": "float"},
        },
    },
}

#: JSON type tags -> python type tuples accepted by the validator
TYPE_TAGS = {
    "str": (str,),
    "int": (int,),
    "float": (int, float),        # ints are acceptable floats
    "bool": (bool,),
}


def validate_event(rec: dict, schema: dict = None):
    """Return a list of violation strings for one event dict (empty when
    valid).  Unknown event types and missing/mistyped required fields are
    violations; extra fields are not."""
    schema = EVENT_SCHEMA if schema is None else schema
    errs = []
    ev = rec.get("event")
    if not isinstance(ev, str):
        return ["missing/invalid 'event' field"]
    spec = schema["events"].get(ev)
    if spec is None:
        return [f"unknown event type {ev!r}"]
    if not isinstance(rec.get("ts"), (int, float)):
        errs.append(f"{ev}: missing/invalid 'ts'")
    for field, tag in spec["required"].items():
        if field not in rec:
            errs.append(f"{ev}: missing required field {field!r}")
            continue
        ok_types = TYPE_TAGS[tag]
        v = rec[field]
        # bool is an int subclass: reject it where an int/float is asked
        if isinstance(v, bool) and tag in ("int", "float"):
            errs.append(f"{ev}: field {field!r} expected {tag}, got bool")
        elif not isinstance(v, ok_types):
            errs.append(f"{ev}: field {field!r} expected {tag}, "
                        f"got {type(v).__name__}")
    return errs
