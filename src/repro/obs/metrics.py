"""Metrics registry: counters, gauges, and log-bucketed histograms.

Every metric lives in a :class:`Registry` keyed on ``(kind, name,
labels)``.  The registry is thread-safe (the serving tier records from
scheduler threads) and near-free when disabled: each recording entry
point is a single attribute test before any allocation happens, so a
``REPRO_OBS=0`` process pays one branch per call site and never creates
a metric object.

Histograms are log-bucketed: bucket ``i`` covers
``(LO * GROWTH**(i-1), LO * GROWTH**i]`` so the memory cost is a small
dict regardless of sample count and any quantile estimate is within one
bucket's relative width (``GROWTH``) of the true order statistic —
tight enough for latency percentiles, unbeatable for the price.

The registry also carries two streams the plain metrics cannot express:

* **events** — schema'd dicts (:mod:`repro.obs.schema`) appended to a
  bounded in-memory buffer and, when ``REPRO_OBS_SINK`` names a path,
  streamed to it as JSON lines;
* **cost samples** — ``(mode, size, wall_s)`` tuples recorded per engine
  step, the raw table an online Eq. 1 cost-model calibration fits.
"""
from __future__ import annotations

import contextlib
import json
import math
import os
import threading
import time
from collections import deque

ENV_ENABLED = "REPRO_OBS"
ENV_SINK = "REPRO_OBS_SINK"
_FALSY = ("0", "false", "off", "no")


def _env_enabled() -> bool:
    return os.environ.get(ENV_ENABLED, "1").strip().lower() not in _FALSY


def _env_sink():
    return os.environ.get(ENV_SINK) or None


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class Counter:
    """Monotonic (between resets) event count."""

    __slots__ = ("name", "labels", "value", "_lock")

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = dict(labels)
        self.value = 0
        self._lock = threading.Lock()

    def inc(self, v=1):
        with self._lock:
            self.value += v

    def reset(self):
        with self._lock:
            self.value = 0


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("name", "labels", "value", "_lock")

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = dict(labels)
        self.value = 0.0
        self._lock = threading.Lock()

    def set(self, v):
        with self._lock:
            self.value = v

    def inc(self, v=1):
        with self._lock:
            self.value += v

    def reset(self):
        with self._lock:
            self.value = 0.0


class Histogram:
    """Log-bucketed histogram with percentile estimation.

    Bucket 0 holds values ``<= LO``; bucket ``i >= 1`` covers
    ``(LO * GROWTH**(i-1), LO * GROWTH**i]``.  ``percentile`` follows
    numpy's default linear interpolation over order statistics, with
    each order statistic represented by its bucket's geometric midpoint
    (clamped to the observed min/max), so estimates land within one
    bucket width of ``numpy.percentile`` on the raw data.
    """

    GROWTH = 2.0 ** 0.25
    LO = 1e-9

    __slots__ = ("name", "labels", "n", "sum", "min", "max", "_counts",
                 "_lock", "_log_growth")

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = dict(labels)
        self.n = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._counts = {}                     # bucket index -> count
        self._lock = threading.Lock()
        self._log_growth = math.log(self.GROWTH)

    def _bucket(self, v: float) -> int:
        if v <= self.LO:
            return 0
        return 1 + int(math.floor(math.log(v / self.LO) / self._log_growth
                                  + 1e-12))

    def bucket_bounds(self, idx: int) -> tuple:
        """(lo, hi] bounds of bucket ``idx``."""
        if idx <= 0:
            return (0.0, self.LO)
        return (self.LO * self.GROWTH ** (idx - 1),
                self.LO * self.GROWTH ** idx)

    def observe(self, v):
        v = float(v)
        with self._lock:
            self.n += 1
            self.sum += v
            self.min = min(self.min, v)
            self.max = max(self.max, v)
            b = self._bucket(v)
            self._counts[b] = self._counts.get(b, 0) + 1

    def reset(self):
        with self._lock:
            self.n = 0
            self.sum = 0.0
            self.min = math.inf
            self.max = -math.inf
            self._counts.clear()

    # -- quantiles -----------------------------------------------------
    def _rep(self, idx: int) -> float:
        lo, hi = self.bucket_bounds(idx)
        rep = math.sqrt(hi * max(lo, self.LO * 1e-3)) if idx > 0 else 0.0
        return min(max(rep, self.min), self.max)

    def _order_stat_bucket(self, k: int) -> int:
        """Bucket index containing the k-th (0-based) order statistic."""
        cum = 0
        for idx in sorted(self._counts):
            cum += self._counts[idx]
            if cum > k:
                return idx
        return max(self._counts) if self._counts else 0

    def percentile(self, p: float) -> float:
        with self._lock:
            if self.n == 0:
                return math.nan
            if self.n == 1:
                return self.min
            target = (p / 100.0) * (self.n - 1)
            k = int(math.floor(target))
            frac = target - k
            lo = self._rep(self._order_stat_bucket(k))
            if frac <= 0 or k + 1 >= self.n:
                return lo
            hi = self._rep(self._order_stat_bucket(k + 1))
            return lo * (1.0 - frac) + hi * frac

    @property
    def p50(self):
        return self.percentile(50)

    @property
    def p95(self):
        return self.percentile(95)

    @property
    def p99(self):
        return self.percentile(99)

    def summary(self) -> dict:
        with self._lock:
            n, s = self.n, self.sum
            mn = self.min if n else None
            mx = self.max if n else None
        out = {"count": n, "sum": s, "min": mn, "max": mx}
        if n:
            out.update(p50=self.percentile(50), p95=self.percentile(95),
                       p99=self.percentile(99))
        return out

    def cumulative_buckets(self):
        """(upper_bound, cumulative_count) pairs, Prometheus-style."""
        with self._lock:
            items = sorted(self._counts.items())
        cum, out = 0, []
        for idx, c in items:
            cum += c
            out.append((self.bucket_bounds(idx)[1], cum))
        return out


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class Registry:
    """One process-wide home for metrics, events, and cost samples.

    ``enabled`` resolves from ``REPRO_OBS`` (anything but
    0/false/off/no enables; the default is ON).  When disabled, every
    recording method returns after one attribute test — no metric
    objects, no events, no sink writes.
    """

    def __init__(self, enabled=None, sink=None, max_events: int = 65536):
        self.enabled = _env_enabled() if enabled is None else bool(enabled)
        self._metrics = {}            # (kind, name, labelkey) -> metric
        self._events = deque(maxlen=max_events)
        self._cost = []               # (mode, size, wall_s, extra) tuples
        self._lock = threading.Lock()
        self._sink_path = _env_sink() if sink is None else sink
        self._sink_file = None
        self._sink_lock = threading.Lock()

    # -- metric construction -------------------------------------------
    def _get(self, kind: str, name: str, labels: dict):
        key = (kind, name, _label_key(labels))
        m = self._metrics.get(key)
        if m is None:
            with self._lock:
                m = self._metrics.get(key)
                if m is None:
                    m = _KINDS[kind](name, labels)
                    self._metrics[key] = m
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get("histogram", name, labels)

    # -- recording (no-ops when disabled) ------------------------------
    def inc(self, name: str, v=1, **labels):
        if not self.enabled:
            return
        self.counter(name, **labels).inc(v)

    def set_gauge(self, name: str, v, **labels):
        if not self.enabled:
            return
        self.gauge(name, **labels).set(v)

    def observe(self, name: str, v, **labels):
        if not self.enabled:
            return
        self.histogram(name, **labels).observe(v)

    def event(self, event: str, **fields):
        if not self.enabled:
            return
        rec = {"event": event, "ts": time.time()}
        rec.update(fields)
        self._events.append(rec)
        self._sink_write(rec)

    def cost_sample(self, mode: str, size, wall_s, **extra):
        """One (partition mode, work size, wall seconds) step timing —
        the raw material for online Eq. 1 cost-model calibration."""
        if not self.enabled:
            return
        with self._lock:
            self._cost.append((str(mode), int(size), float(wall_s), extra))

    # -- reads ---------------------------------------------------------
    def cost_samples(self, mode=None):
        """``(mode, size, wall_s)`` tuples recorded so far, optionally
        filtered to one partition mode."""
        with self._lock:
            rows = list(self._cost)
        return [(m, s, w) for m, s, w, _ in rows
                if mode is None or m == mode]

    def cost_samples_full(self, mode=None):
        with self._lock:
            rows = list(self._cost)
        return [r for r in rows if mode is None or r[0] == mode]

    def events(self, event=None):
        out = list(self._events)
        if event is not None:
            out = [e for e in out if e.get("event") == event]
        return out

    def metrics(self):
        with self._lock:
            return dict(self._metrics)

    def snapshot(self) -> dict:
        """{kind: {"name{k=v,...}": value-or-summary}} for reporting."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for (kind, name, lk), m in self.metrics().items():
            label_s = ",".join(f"{k}={v}" for k, v in lk)
            key = f"{name}{{{label_s}}}" if label_s else name
            if kind == "counter":
                out["counters"][key] = m.value
            elif kind == "gauge":
                out["gauges"][key] = m.value
            else:
                out["histograms"][key] = m.summary()
        return out

    # -- lifecycle -----------------------------------------------------
    def reset(self):
        """Drop every metric, event, and cost sample (enabled/sink kept)."""
        with self._lock:
            self._metrics.clear()
            self._cost.clear()
        self._events.clear()

    def reset_metric(self, name: str, **labels):
        """Reset every metric series called ``name`` whose labels contain
        the given items (hit-rate segmentation: resetting a layout's
        series must not disturb other layouts')."""
        want = set(_label_key(labels))
        for (kind, n, lk), m in self.metrics().items():
            if n == name and want <= set(lk):
                m.reset()

    def set_sink(self, path):
        """Redirect the streaming JSONL sink (None closes it)."""
        with self._sink_lock:
            if self._sink_file is not None:
                self._sink_file.close()
                self._sink_file = None
            self._sink_path = str(path) if path else None

    def _sink_write(self, rec: dict):
        if self._sink_path is None:
            return
        with self._sink_lock:
            if self._sink_path is None:
                return
            if self._sink_file is None:
                self._sink_file = open(self._sink_path, "a",
                                       encoding="utf-8")
            self._sink_file.write(json.dumps(rec, default=_json_default)
                                  + "\n")
            self._sink_file.flush()

    def close(self):
        self.set_sink(self._sink_path)        # closes the open handle


def _json_default(o):
    for cast in (int, float):
        try:
            return cast(o)
        except (TypeError, ValueError):
            continue
    return str(o)


# ----------------------------------------------------------------------
# process-default registry + module-level convenience API
# ----------------------------------------------------------------------

_default = Registry()


def registry() -> Registry:
    return _default


def enabled() -> bool:
    return _default.enabled


def set_enabled(value=None) -> bool:
    """Force telemetry on/off; ``None`` re-reads ``REPRO_OBS``."""
    _default.enabled = _env_enabled() if value is None else bool(value)
    return _default.enabled


@contextlib.contextmanager
def override_enabled(value: bool):
    """Temporarily force the default registry on/off (tests)."""
    prev = _default.enabled
    _default.enabled = bool(value)
    try:
        yield
    finally:
        _default.enabled = prev


def counter(name: str, **labels) -> Counter:
    return _default.counter(name, **labels)


def gauge(name: str, **labels) -> Gauge:
    return _default.gauge(name, **labels)


def histogram(name: str, **labels) -> Histogram:
    return _default.histogram(name, **labels)


def inc(name: str, v=1, **labels):
    _default.inc(name, v, **labels)


def set_gauge(name: str, v, **labels):
    _default.set_gauge(name, v, **labels)


def observe(name: str, v, **labels):
    _default.observe(name, v, **labels)


def event(event_name: str, **fields):
    _default.event(event_name, **fields)


def cost_sample(mode: str, size, wall_s, **extra):
    _default.cost_sample(mode, size, wall_s, **extra)


def cost_samples(mode=None):
    return _default.cost_samples(mode)


def events(event_name=None):
    return _default.events(event_name)


def snapshot() -> dict:
    return _default.snapshot()


def reset():
    _default.reset()
