"""Partition-centric graph layout (paper §3.1-3.3).

Builds the static data structures PPM needs:

  * index-based partitioning: partition ``p`` owns vertices
    ``[p*q, (p+1)*q)`` (paper §3.1);
  * the 2D block grid of bins: edges bucketed by
    ``(src_partition, dst_partition)`` (paper §3.2, Fig. 3).  *Message slots*
    (the scatter-side ``data_bin``) are laid out row-major — partition ``p``
    writes its whole bin row contiguously, as in the paper's Scatter phase.
    *Edges* (the gather-side ``dc_bin``: pre-written adjacency) are laid out
    column-major — partition ``p'`` reads its whole bin column contiguously,
    as in the paper's Gather phase;
  * the PNG (Partition-Node bipartite Graph) layout for destination-centric
    scatter: one message slot per (src vertex, dst partition) pair; the wire
    carries values only (§3.3);
  * per-partition constants for the Eq. 1 communication cost model.

Everything is statically shaped: edge blocks and message blocks are padded to
tile multiples so a Pallas grid step maps to exactly one tile inside one
(p, p') block, blocked VMEM tiles are indexed by scalar-prefetched per-tile
partition ids, and tiles whose source partition is inactive are skipped — the
TPU analogue of the 2-level active list.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .csr import Graph


def _pad_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult if mult > 1 else x


def _pad_to_array(x: np.ndarray, mult: int) -> np.ndarray:
    if mult <= 1:
        return x.astype(np.int64)
    return (((x + mult - 1) // mult) * mult).astype(np.int64)


@dataclasses.dataclass
class Layout:
    """Static partition-centric layout for a graph.

    Vertex space is padded to ``n_pad = k*q``; the sentinel vertex id is
    ``n_pad`` and the sentinel message slot is ``num_msgs`` (identity-valued).
    """

    # ---- partitioning ----
    k: int                    # number of partitions
    q: int                    # vertices per partition
    n: int                    # real vertex count
    m: int                    # real edge count
    weighted: bool

    # ---- PNG / message slots (scatter side), row-major (p, p', src) ----
    png_src: np.ndarray       # int32[NM] global src id per slot (sentinel n_pad)
    png_src_local: np.ndarray  # int32[NM] src id within its partition (0 on pads)
    png_off: np.ndarray       # int64[k*k+1] slot offsets, block key = p*k + p'
    png_tile_part: np.ndarray  # int32[NM/msg_tile] src partition per slot tile

    # ---- dc_bin: gather-side edge arrays, column-major (p', p, src, dst) ----
    msg_slot: np.ndarray      # int32[NE] message slot per edge (sentinel NM)
    edge_dst: np.ndarray      # int32[NE] global dst id (sentinel n_pad)
    edge_src_local: np.ndarray  # int32[NE] src id within src partition (0 pads)
    edge_dst_local: np.ndarray  # int32[NE] dst id within dst partition (0 pads)
    edge_valid: np.ndarray    # bool[NE] real edge?
    edge_w: Optional[np.ndarray]   # float32[NE] | None
    blk_off: np.ndarray       # int64[k*k+1] edge offsets, block key = p'*k + p

    # ---- per-edge-tile metadata (kernel blocking + predication) ----
    edge_tile: int
    msg_tile: int
    fold_tile: int            # message-tile of the blocked segmented fold
    fold_q: int               # bucket width of the two-level (over-cap) fold
    tile_src_part: np.ndarray  # int32[NT] source partition of each edge tile
    tile_dst_part: np.ndarray  # int32[NT] destination partition (non-decreasing)
    tile_first: np.ndarray     # bool[NT] first tile of its destination partition
    part_has_tiles: np.ndarray  # bool[k] destination partition receives edges

    # ---- original CSR (source-centric frontier expansion) ----
    csr_indptr: np.ndarray    # int64[n_pad + 2] (sentinel row n_pad: degree 0)
    csr_indices: np.ndarray   # int32[m]
    csr_w: Optional[np.ndarray]

    # ---- per-partition constants (Eq. 1) ----
    part_edges: np.ndarray    # int64[k]  E^p (out-edges of partition p)
    part_msgs: np.ndarray     # int64[k]  r*E^p = PNG slots of p
    deg: np.ndarray           # int64[n_pad] out-degree (0 on pads)

    @property
    def n_pad(self) -> int:
        return self.k * self.q

    @property
    def num_msgs(self) -> int:
        return len(self.png_src)

    @property
    def num_edges(self) -> int:
        return len(self.msg_slot)

    @property
    def num_edge_tiles(self) -> int:
        return len(self.tile_src_part)

    def part_of(self, v):
        return v // self.q

    # -- Eq. 1 cost model constants (bytes; d_i = d_v = 4 as in the paper) --
    def dc_cost_bytes(self, d_i: int = 4, d_v: int = 4) -> np.ndarray:
        """Per-partition DC bytes: rE^p*d_i + k*d_i + 2rE^p*d_v + E^p*d_i."""
        return (self.part_msgs * d_i + self.k * d_i
                + 2 * self.part_msgs * d_v + self.part_edges * d_i)

    def sc_cost_coeff(self, d_i: int = 4, d_v: int = 4) -> np.ndarray:
        """Per-active-edge SC bytes: 2r*d_v + 3*d_i (paper's approximation)."""
        r = self.part_msgs / np.maximum(self.part_edges, 1)
        return 2.0 * r * d_v + 3.0 * d_i


def resolve_k(n: int, k: Optional[int] = None, parallel_units: int = 8,
              cache_vertices: Optional[int] = None) -> int:
    """The paper's §3.1 partition-count rule: enough partitions that one
    partition's vertex data fits the private cache (``cache_vertices``),
    and ``k >= 4 * parallel_units``; clamped to [1, n]."""
    if k is None:
        k = max(4 * parallel_units, 1)
        if cache_vertices is not None:
            k = max(k, -(-n // cache_vertices))
    return max(1, min(k, max(1, n)))


def build_layout(g: Graph, k: Optional[int] = None,
                 parallel_units: int = 8,
                 q_mult: int = 8,
                 edge_tile: Optional[int] = None,
                 msg_tile: Optional[int] = None,
                 fold_tile: Optional[int] = None,
                 fold_q: Optional[int] = None,
                 cache_vertices: Optional[int] = None) -> Layout:
    """Build the partition-centric layout.

    ``k`` defaults to the paper's rule (§3.1), see :func:`resolve_k`.

    ``edge_tile``/``msg_tile``/``fold_tile``/``fold_q`` left unset resolve
    through the :mod:`repro.backend.tuning` cache: an ``autotune()`` sweep
    recorded for this platform/backend/graph family wins, otherwise the
    static defaults (256/128/256/256) apply.  ``fold_q`` additionally
    honours the ``REPRO_FOLD_Q`` environment knob when no sweep covers
    this family.
    """
    n, m = g.n, g.m
    k = resolve_k(n, k, parallel_units, cache_vertices)
    if edge_tile is None or msg_tile is None or fold_tile is None \
            or fold_q is None:
        import os

        from ..backend.tuning import resolve_geometry
        from ..kernels.fold_block import ENV_FOLD_TILE, default_fold_tile
        from ..kernels.fold_two_level import ENV_FOLD_Q, default_fold_q
        geom = resolve_geometry(n, m, k, weighted=g.weighted)
        edge_tile = geom.edge_tile if edge_tile is None else edge_tile
        msg_tile = geom.msg_tile if msg_tile is None else msg_tile
        # the REPRO_FOLD_TILE / REPRO_FOLD_Q knobs outrank the tuned or
        # static geometry so an operator can steer deployed layouts
        # without a re-sweep (engines always pass the layout's values to
        # FoldKernel, so this is where the env must be honoured)
        if fold_tile is None:
            fold_tile = (default_fold_tile() if os.environ.get(ENV_FOLD_TILE)
                         else geom.fold_tile)
        if fold_q is None:
            fold_q = (default_fold_q() if os.environ.get(ENV_FOLD_Q)
                      else geom.fold_q)
    q = _pad_to(-(-n // k), q_mult)
    n_pad = k * q

    src = np.repeat(np.arange(n, dtype=np.int64), g.out_degrees())
    dst = g.indices.astype(np.int64)
    w = g.weights
    sp = src // q
    dp = dst // q

    # --- scatter-side (row-major) sort: (p, p', src, dst) ---
    sblk = sp * k + dp
    order_s = np.argsort(sblk, kind="stable")      # CSR input is (src,dst)-sorted
    src, dst, sblk = src[order_s], dst[order_s], sblk[order_s]
    sp, dp = sp[order_s], dp[order_s]
    if w is not None:
        w = w[order_s]

    # message slots: one per unique (src, dst-partition) pair
    new_slot = np.ones(m, dtype=bool)
    if m > 1:
        same = (src[1:] == src[:-1]) & (sblk[1:] == sblk[:-1])
        new_slot[1:] = ~same
    slot_of_edge = np.cumsum(new_slot) - 1
    num_msgs = int(slot_of_edge[-1] + 1) if m else 0
    slot_src = src[new_slot]
    slot_blk = sblk[new_slot]

    blk_msg_cnt = np.bincount(slot_blk, minlength=k * k)
    blk_msg_pad = _pad_to_array(blk_msg_cnt, msg_tile)
    png_off = np.concatenate([[0], np.cumsum(blk_msg_pad)])
    nm_pad = int(png_off[-1])
    slot_rank = np.arange(num_msgs) - np.repeat(
        np.concatenate([[0], np.cumsum(blk_msg_cnt)])[:-1], blk_msg_cnt)
    spos = png_off[slot_blk] + slot_rank          # padded slot position
    slot_pad_of_edge = spos[slot_of_edge]

    png_src = np.full(nm_pad, n_pad, dtype=np.int32)
    png_src[spos] = slot_src
    png_src_local = np.zeros(nm_pad, dtype=np.int32)
    png_src_local[spos] = slot_src - (slot_src // q) * q
    if nm_pad:
        png_tile_part = (png_src.reshape(-1, msg_tile)[:, 0] * 0)  # placeholder
        # slot tiles lie inside one block (blocks padded to msg_tile)
        ntm = nm_pad // msg_tile
        tile_blk_m = np.searchsorted(png_off[1:], np.arange(ntm) * msg_tile,
                                     side="right")
        png_tile_part = (tile_blk_m // k).astype(np.int32)
    else:
        png_tile_part = np.zeros(0, dtype=np.int32)

    # --- gather-side (column-major) sort: (p', p, src, dst) ---
    dblk = dp * k + sp
    order_d = np.argsort(dblk, kind="stable")
    src_d, dst_d, dblk_s = src[order_d], dst[order_d], dblk[order_d]
    slot_pad_d = slot_pad_of_edge[order_d]
    w_d = w[order_d] if w is not None else None

    blk_edge_cnt = np.bincount(dblk_s, minlength=k * k)
    blk_edge_pad = _pad_to_array(blk_edge_cnt, edge_tile)
    blk_off = np.concatenate([[0], np.cumsum(blk_edge_pad)])
    ne_pad = int(blk_off[-1])
    edge_rank = np.arange(m) - np.repeat(
        np.concatenate([[0], np.cumsum(blk_edge_cnt)])[:-1], blk_edge_cnt)
    epos = blk_off[dblk_s] + edge_rank

    msg_slot = np.full(ne_pad, nm_pad, dtype=np.int32)
    msg_slot[epos] = slot_pad_d
    edge_dst = np.full(ne_pad, n_pad, dtype=np.int32)
    edge_dst[epos] = dst_d
    edge_src_local = np.zeros(ne_pad, dtype=np.int32)
    edge_src_local[epos] = src_d - (src_d // q) * q
    edge_dst_local = np.zeros(ne_pad, dtype=np.int32)
    edge_dst_local[epos] = dst_d - (dst_d // q) * q
    edge_valid = np.zeros(ne_pad, dtype=bool)
    edge_valid[epos] = True
    edge_w = None
    if w_d is not None:
        edge_w = np.zeros(ne_pad, dtype=np.float32)
        edge_w[epos] = w_d

    # per-tile metadata (each tile lies inside exactly one block)
    nt = ne_pad // edge_tile
    tile_blk = np.searchsorted(blk_off[1:], np.arange(nt) * edge_tile,
                               side="right")
    tile_dst_part = (tile_blk // k).astype(np.int32)
    tile_src_part = (tile_blk % k).astype(np.int32)
    tile_first = np.ones(nt, dtype=bool)
    tile_first[1:] = tile_dst_part[1:] != tile_dst_part[:-1]
    part_has_tiles = np.zeros(k, dtype=bool)
    part_has_tiles[tile_dst_part] = True

    # CSR with sentinel row (vertex n_pad: degree 0) for SC expansion
    csr_indptr = np.zeros(n_pad + 2, dtype=np.int64)
    csr_indptr[1:n + 1] = g.indptr[1:]
    csr_indptr[n + 1:] = m

    part_edges = np.zeros(k, dtype=np.int64)
    np.add.at(part_edges, sp, 1)
    part_msgs = np.zeros(k, dtype=np.int64)
    np.add.at(part_msgs, slot_blk // k, 1)
    deg = np.zeros(n_pad, dtype=np.int64)
    deg[:n] = g.out_degrees()

    return Layout(
        k=k, q=q, n=n, m=m, weighted=g.weighted,
        png_src=png_src, png_src_local=png_src_local, png_off=png_off,
        png_tile_part=png_tile_part,
        msg_slot=msg_slot, edge_dst=edge_dst,
        edge_src_local=edge_src_local, edge_dst_local=edge_dst_local,
        edge_valid=edge_valid, edge_w=edge_w, blk_off=blk_off,
        edge_tile=edge_tile, msg_tile=msg_tile, fold_tile=fold_tile,
        fold_q=fold_q,
        tile_src_part=tile_src_part, tile_dst_part=tile_dst_part,
        tile_first=tile_first, part_has_tiles=part_has_tiles,
        csr_indptr=csr_indptr, csr_indices=g.indices.astype(np.int32),
        csr_w=g.weights,
        part_edges=part_edges, part_msgs=part_msgs, deg=deg,
    )
