"""Device-sharded partition-centric layout (DESIGN.md §2, §6).

Level-1 partitioning: ``k`` partitions are distributed over ``D`` devices
(``kpd = k/D`` partitions per device, index-contiguous — the same rule the
paper uses for threads).  The 2D bin grid becomes a per-(src-device,
dst-device) exchange:

  * DC mode: the scatter-side message buffer is ``out[D, S]`` (slot tiles
    grouped by destination device, values only); one dense ``all_to_all``
    delivers every bin column to its owner, after which the *statically
    resident* ``in_msg_slot`` / ``in_dst_local`` arrays (the paper's
    pre-written ``dc_bin``) drive a local segmented fold.
  * SC mode: out-edges grouped by destination device with per-group
    compaction and a ``ragged_all_to_all`` — wire bytes proportional to the
    active edges, the paper's work-efficiency on the ICI.

All per-device arrays are padded to the max across devices (SPMD needs equal
shapes); real sizes are kept for the cost model.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .layout import Layout, _pad_to


@dataclasses.dataclass
class ShardedLayout:
    D: int
    kpd: int                 # partitions per device
    q: int
    nv: int                  # vertices per device = kpd * q
    n: int                   # real vertex count (global)
    S: int                   # message-slot capacity per (src,dst) device pair
    weighted: bool
    fold_tile: int           # blocked-fold message tile (from the Layout)
    fold_q: int              # two-level fold bucket width (from the Layout)

    # ---- DC scatter side (per source device) ----
    out_src_local: np.ndarray   # int32[D, D, S]
    out_valid: np.ndarray       # bool [D, D, S]

    # ---- DC gather side (per destination device) ----
    in_msg_slot: np.ndarray     # int32[D, NEd] -> index into recv[D*S] (sentinel D*S)
    in_dst_local: np.ndarray    # int32[D, NEd] (sentinel nv)
    in_valid: np.ndarray        # bool [D, NEd]
    in_w: Optional[np.ndarray]  # f32  [D, NEd]

    # ---- SC side: out-edges grouped by destination device ----
    oe_src_local: np.ndarray    # int32[D, NEs]
    oe_dst_local: np.ndarray    # int32[D, NEs] (local to the *destination*)
    oe_valid: np.ndarray        # bool [D, NEs]
    oe_w: Optional[np.ndarray]  # f32  [D, NEs]
    oe_group_off: np.ndarray    # int64[D, D+1] group boundaries
    cap_in: int                 # SC receive capacity (max in-edges/device)
    cap_pair: int               # SC per-(src,dst)-pair capacity

    # host-side cost-model stats
    part_edges: np.ndarray      # int64[k] (global, from Layout)
    part_msgs: np.ndarray
    deg: np.ndarray             # int64[D*nv] sharded-order out-degrees

    @property
    def ne_d(self) -> int:
        return self.in_msg_slot.shape[1]

    @property
    def ne_s(self) -> int:
        return self.oe_src_local.shape[1]

    def arrays(self) -> dict:
        """The pytree of device-partitioned arrays fed into the step fn."""
        d = dict(out_src_local=self.out_src_local, out_valid=self.out_valid,
                 in_msg_slot=self.in_msg_slot, in_dst_local=self.in_dst_local,
                 in_valid=self.in_valid,
                 oe_src_local=self.oe_src_local, oe_dst_local=self.oe_dst_local,
                 oe_valid=self.oe_valid, oe_group_off=self.oe_group_off)
        if self.weighted:
            d["in_w"] = self.in_w
            d["oe_w"] = self.oe_w
        return d


def shard_layout(L: Layout, D: int) -> ShardedLayout:
    """Regroup a single-device Layout for D devices (k must divide by D)."""
    k, q = L.k, L.q
    assert k % D == 0, f"k={k} not divisible by D={D}"
    kpd = k // D
    nv = kpd * q
    n_pad = L.n_pad
    nm_pad = L.num_msgs

    # ---------- DC scatter side: regroup PNG slots by device pair ----------
    slot_blk = np.repeat(np.arange(k * k, dtype=np.int64),
                         np.diff(L.png_off))
    sp_, dp_ = slot_blk // k, slot_blk % k
    sdev, ddev = sp_ // kpd, dp_ // kpd
    pair = sdev * D + ddev
    order = np.argsort(pair, kind="stable")
    pair_cnt = np.bincount(pair, minlength=D * D)
    S = _pad_to(int(pair_cnt.max(initial=0)), 8)
    rank = np.arange(nm_pad) - np.repeat(
        np.concatenate([[0], np.cumsum(pair_cnt)])[:-1], pair_cnt)
    pos = np.empty(nm_pad, dtype=np.int64)
    pos[order] = rank                                    # position within pair
    # out buffers
    out_src_local = np.zeros((D, D, S), dtype=np.int32)
    out_valid = np.zeros((D, D, S), dtype=bool)
    real = L.png_src < n_pad
    out_src_local[sdev[real], ddev[real], pos[real]] = \
        (L.png_src[real] - sdev[real].astype(np.int64) * nv).astype(np.int32)
    out_valid[sdev[real], ddev[real], pos[real]] = True
    # receive-side index of each slot: row = src device, col = pos
    slot_recv = (sdev * S + pos).astype(np.int64)        # in [0, D*S)

    # ---------- DC gather side: per-destination-device edge slices ----------
    # gather-order blocks are keyed p'*k + p, so each device's incoming edges
    # are one contiguous range of the global arrays.
    dev_edge_lo = L.blk_off[np.arange(D) * kpd * k]
    dev_edge_hi = L.blk_off[(np.arange(D) + 1) * kpd * k]
    ne_d = _pad_to(int((dev_edge_hi - dev_edge_lo).max(initial=0)),
                   L.edge_tile)
    in_msg_slot = np.full((D, ne_d), D * S, dtype=np.int32)
    in_dst_local = np.full((D, ne_d), nv, dtype=np.int32)
    in_valid = np.zeros((D, ne_d), dtype=bool)
    in_w = np.zeros((D, ne_d), dtype=np.float32) if L.weighted else None
    for d in range(D):
        lo, hi = int(dev_edge_lo[d]), int(dev_edge_hi[d])
        c = hi - lo
        ms = L.msg_slot[lo:hi]
        ok = ms < nm_pad
        slot_mapped = np.full(c, D * S, dtype=np.int32)
        slot_mapped[ok] = slot_recv[ms[ok]].astype(np.int32)
        in_msg_slot[d, :c] = slot_mapped
        dst = L.edge_dst[lo:hi].astype(np.int64)
        dok = dst < n_pad
        dl = np.full(c, nv, dtype=np.int32)
        dl[dok] = (dst[dok] - d * nv).astype(np.int32)
        in_dst_local[d, :c] = dl
        in_valid[d, :c] = L.edge_valid[lo:hi]
        if L.weighted:
            in_w[d, :c] = L.edge_w[lo:hi]

    # ---------- SC side: out-edges grouped by (src device, dst device) ------
    deg_np = L.deg
    src_g = np.repeat(np.arange(L.n, dtype=np.int64),
                      deg_np[:L.n].astype(np.int64))
    dst_g = L.csr_indices.astype(np.int64)
    w_g = L.csr_w
    sdev_e = src_g // nv
    ddev_e = dst_g // nv
    okey = sdev_e * D + ddev_e
    eorder = np.argsort(okey, kind="stable")
    src_g, dst_g, okey = src_g[eorder], dst_g[eorder], okey[eorder]
    sdev_e, ddev_e = sdev_e[eorder], ddev_e[eorder]
    if w_g is not None:
        w_g = w_g[eorder]
    per_dev_cnt = np.bincount(sdev_e, minlength=D)
    ne_s = _pad_to(int(per_dev_cnt.max(initial=0)), 8)
    oe_src_local = np.zeros((D, ne_s), dtype=np.int32)
    oe_dst_local = np.zeros((D, ne_s), dtype=np.int32)
    oe_valid = np.zeros((D, ne_s), dtype=bool)
    oe_w = np.zeros((D, ne_s), dtype=np.float32) if L.weighted else None
    oe_group_off = np.zeros((D, D + 1), dtype=np.int64)
    dev_starts = np.concatenate([[0], np.cumsum(per_dev_cnt)])
    grp_cnt = np.bincount(okey, minlength=D * D).reshape(D, D)
    for d in range(D):
        lo, hi = int(dev_starts[d]), int(dev_starts[d + 1])
        c = hi - lo
        oe_src_local[d, :c] = (src_g[lo:hi] - d * nv).astype(np.int32)
        oe_dst_local[d, :c] = (dst_g[lo:hi]
                               - ddev_e[lo:hi] * nv).astype(np.int32)
        oe_valid[d, :c] = True
        if w_g is not None:
            oe_w[d, :c] = w_g[lo:hi]
        oe_group_off[d, 1:] = np.cumsum(grp_cnt[d])
    in_cnt = np.bincount(np.minimum(dst_g // nv, D - 1), minlength=D)
    cap_in = _pad_to(int(in_cnt.max(initial=1)), 8)
    cap_pair = _pad_to(int(grp_cnt.max(initial=1)), 8)

    deg_pad = np.zeros(D * nv, dtype=np.int64)
    deg_pad[:n_pad] = deg_np
    return ShardedLayout(
        D=D, kpd=kpd, q=q, nv=nv, n=L.n, S=S, weighted=L.weighted,
        fold_tile=L.fold_tile, fold_q=L.fold_q,
        out_src_local=out_src_local, out_valid=out_valid,
        in_msg_slot=in_msg_slot, in_dst_local=in_dst_local,
        in_valid=in_valid, in_w=in_w,
        oe_src_local=oe_src_local, oe_dst_local=oe_dst_local,
        oe_valid=oe_valid, oe_w=oe_w, oe_group_off=oe_group_off,
        cap_in=cap_in, cap_pair=cap_pair,
        part_edges=L.part_edges, part_msgs=L.part_msgs, deg=deg_pad)


def sharded_spec(n: int, m: int, D: int, k_per_dev: int = 4,
                 weighted: bool = False, slot_slack: float = 1.3,
                 edge_slack: float = 1.3):
    """Shape-only ShardedLayout stand-in for the AOT dry-run.

    Buffer sizes follow the same formulas as :func:`shard_layout` but from
    expectations: slots/pair ~ m/D^2 (power-law graphs at device granularity
    are near-uniform under index hashing), edges/device ~ m/D.
    """
    import jax
    k = D * k_per_dev
    q = _pad_to(-(-n // k), 128)
    nv = k_per_dev * q
    S = _pad_to(int(m / (D * D) * slot_slack) + 8, 8)
    ne_d = _pad_to(int(m / D * edge_slack) + 8, 256)
    ne_s = _pad_to(int(m / D * edge_slack) + 8, 8)
    f32 = jax.ShapeDtypeStruct
    arrs = dict(
        out_src_local=f32((D, D, S), np.int32),
        out_valid=f32((D, D, S), np.bool_),
        in_msg_slot=f32((D, ne_d), np.int32),
        in_dst_local=f32((D, ne_d), np.int32),
        in_valid=f32((D, ne_d), np.bool_),
        oe_src_local=f32((D, ne_s), np.int32),
        oe_dst_local=f32((D, ne_s), np.int32),
        oe_valid=f32((D, ne_s), np.bool_),
        oe_group_off=f32((D, D + 1), np.int64),
    )
    if weighted:
        arrs["in_w"] = f32((D, ne_d), np.float32)
        arrs["oe_w"] = f32((D, ne_s), np.float32)
    cap_pair = _pad_to(int(m / (D * D) * edge_slack) + 8, 8)
    meta = dict(D=D, kpd=k_per_dev, q=q, nv=nv, S=S, cap_in=ne_s,
                cap_pair=cap_pair, weighted=weighted)
    return arrs, meta
