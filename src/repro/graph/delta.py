"""Dynamic-graph deltas over the partition-centric layout.

GPOP's partition structure is the natural delta unit: a :class:`DeltaBuffer`
accumulates edge insertions/deletions bucketed by *destination partition*
(the gather-side bin column the edit lands in), and :func:`apply_delta`
rebuilds only the bins owned by dirty *source* partitions — every (p, p')
block with a clean source partition p keeps its CSR rows, its PNG slot row
and its gather-column content byte-for-byte, so per-partition content tags
(and the semantic-cache entries keyed on them) survive the edit.

Semantics
---------
The buffer edits the *edge set* of a fixed vertex set:

  * ``insert(u, v, w)`` adds edge ``(u, v)`` (or overwrites its weight if it
    already exists);
  * ``delete(u, v)`` removes ``(u, v)`` if present (a no-op otherwise);
  * the last operation on a given ``(u, v)`` wins;
  * the vertex set never changes — deltas edit edges only, so ``k``/``q``
    and the partition map are stable across :func:`apply_delta` (that
    stability is what makes per-partition reuse and scoped cache
    invalidation possible at all).

Parallel duplicate edges inside a *dirty* partition are collapsed by an
edit that touches their ``(u, v)`` key; untouched duplicates in clean
partitions are preserved verbatim.

Equivalence contract
--------------------
``apply_delta(layout, delta)`` is bit-exact equal to
``build_layout(delta.edit_graph(g), k=layout.k, ...)`` with the old
layout's tile geometry — every array field, including pad sentinels.
``tests/test_delta.py`` asserts this field-by-field.
"""
from __future__ import annotations

import time
from typing import Dict, Iterable, Optional, Tuple

import numpy as np

from .csr import Graph, from_edges
from .layout import Layout, _pad_to_array

__all__ = ["DeltaBuffer", "apply_delta"]

_INS = "+"
_DEL = "-"


def _as_1d_int(x) -> np.ndarray:
    a = np.atleast_1d(np.asarray(x, dtype=np.int64))
    if a.ndim != 1:
        raise ValueError(f"expected scalar or 1-D vertex ids, got shape {a.shape}")
    return a


class DeltaBuffer:
    """Edge insertions/deletions against one layout's partitioning.

    Operations are bucketed by destination partition ``dst // q`` — the
    bin column the edit lands in.  ``for_layout`` is the usual
    constructor; the buffer validates every endpoint against ``n`` (the
    vertex set is fixed; grow it with a full ``build_layout``).
    """

    def __init__(self, k: int, q: int, n: int):
        if k <= 0 or q < 0 or n < 0 or n > k * q:
            raise ValueError(f"inconsistent partitioning k={k} q={q} n={n}")
        self.k = int(k)
        self.q = int(q)
        self.n = int(n)
        # dst-partition buckets: dp -> {(u, v): ("+", w) | ("-", None)}
        self._buckets: Dict[int, Dict[Tuple[int, int], Tuple[str, Optional[float]]]] = {}

    @classmethod
    def for_layout(cls, layout: Layout) -> "DeltaBuffer":
        return cls(layout.k, layout.q, layout.n)

    # ---- mutation ----

    def _check(self, src: np.ndarray, dst: np.ndarray) -> None:
        for name, a in (("src", src), ("dst", dst)):
            if a.size and (a.min() < 0 or a.max() >= self.n):
                raise ValueError(
                    f"{name} id out of range [0, {self.n}) — deltas edit "
                    f"edges over a fixed vertex set")

    def _put(self, u: int, v: int, op: Tuple[str, Optional[float]]) -> None:
        dp = v // self.q if self.q else 0
        self._buckets.setdefault(dp, {})[(u, v)] = op

    def insert(self, src, dst, w=None) -> "DeltaBuffer":
        """Queue edge insertions (scalars or equal-length arrays)."""
        su, sv = _as_1d_int(src), _as_1d_int(dst)
        if su.shape != sv.shape:
            raise ValueError("src/dst length mismatch")
        self._check(su, sv)
        if w is None:
            ws = [None] * len(su)
        else:
            wa = np.atleast_1d(np.asarray(w, dtype=np.float32))
            if wa.shape != su.shape:
                raise ValueError("weights length mismatch")
            ws = [float(x) for x in wa]
        for u, v, wi in zip(su.tolist(), sv.tolist(), ws):
            self._put(u, v, (_INS, wi))
        return self

    def delete(self, src, dst) -> "DeltaBuffer":
        """Queue edge deletions (scalars or equal-length arrays)."""
        su, sv = _as_1d_int(src), _as_1d_int(dst)
        if su.shape != sv.shape:
            raise ValueError("src/dst length mismatch")
        self._check(su, sv)
        for u, v in zip(su.tolist(), sv.tolist()):
            self._put(u, v, (_DEL, None))
        return self

    # ---- inspection ----

    def _iter_ops(self) -> Iterable[Tuple[int, int, str, Optional[float]]]:
        for dp in sorted(self._buckets):
            for (u, v), (op, w) in self._buckets[dp].items():
                yield u, v, op, w

    def __len__(self) -> int:
        return sum(len(b) for b in self._buckets.values())

    def __bool__(self) -> bool:
        return any(self._buckets.values())

    @property
    def num_inserts(self) -> int:
        return sum(1 for *_ignored, op, _w in self._iter_ops() if op == _INS)

    @property
    def num_deletes(self) -> int:
        return len(self) - self.num_inserts

    @property
    def insertions_only(self) -> bool:
        """True iff the delta only adds/overwrites edges — the case where
        an old converged min-monoid state stays a pointwise upper bound of
        the new fixpoint (so warm resume and landmark migration are sound;
        deletions can *raise* distances and need a cold start)."""
        return self.num_deletes == 0

    def inserts(self):
        """(src, dst, w|None) int64/int64/float32 arrays, (src, dst)-sorted."""
        rows = [(u, v, w) for u, v, op, w in self._iter_ops() if op == _INS]
        rows.sort()
        src = np.array([r[0] for r in rows], dtype=np.int64)
        dst = np.array([r[1] for r in rows], dtype=np.int64)
        if any(r[2] is not None for r in rows):
            w = np.array([1.0 if r[2] is None else r[2] for r in rows],
                         dtype=np.float32)
        else:
            w = None
        return src, dst, w

    def deletes(self):
        """(src, dst) int64 arrays, (src, dst)-sorted."""
        rows = sorted((u, v) for u, v, op, _w in self._iter_ops()
                      if op == _DEL)
        return (np.array([r[0] for r in rows], dtype=np.int64),
                np.array([r[1] for r in rows], dtype=np.int64))

    def src_partitions(self) -> np.ndarray:
        """Partitions whose out-rows (CSR + scatter/gather bins) change."""
        parts = {u // self.q if self.q else 0
                 for u, _v, _op, _w in self._iter_ops()}
        return np.array(sorted(parts), dtype=np.int32)

    def dst_partitions(self) -> np.ndarray:
        """The destination-partition bucket keys holding queued ops."""
        return np.array(sorted(dp for dp, b in self._buckets.items() if b),
                        dtype=np.int32)

    def dirty_partitions(self) -> np.ndarray:
        """Partitions owning either endpoint of any queued op — the scope
        of cache invalidation (a partition's converged state can change
        when either its out-edges or its in-edges do)."""
        parts = set()
        for u, v, _op, _w in self._iter_ops():
            if self.q:
                parts.add(u // self.q)
                parts.add(v // self.q)
            else:
                parts.add(0)
        return np.array(sorted(parts), dtype=np.int32)

    def touched(self) -> np.ndarray:
        """bool[n_pad] mask of delta endpoints — the initial frontier for
        incremental recompute (``Engine.run(resume_from=, touched=)``)."""
        mask = np.zeros(self.k * self.q, dtype=bool)
        for u, v, _op, _w in self._iter_ops():
            mask[u] = True
            mask[v] = True
        return mask

    # ---- reference edit (full-rebuild baseline) ----

    def edit_graph(self, g: Graph) -> Graph:
        """Apply the buffered ops to ``g`` and return the edited graph —
        the reference for the full-rebuild baseline
        (``build_layout(delta.edit_graph(g), ...)``)."""
        if g.n != self.n:
            raise ValueError(f"graph has n={g.n}, buffer built for n={self.n}")
        n = self.n
        src = np.repeat(np.arange(n, dtype=np.int64), g.out_degrees())
        dst = g.indices.astype(np.int64)
        w = g.weights
        ins_src, ins_dst, ins_w = self.inserts()
        del_src, del_dst = self.deletes()
        nk = max(n, 1)
        drop_keys = np.concatenate([ins_src * nk + ins_dst,
                                    del_src * nk + del_dst])
        keep = ~np.isin(src * nk + dst, drop_keys)
        src, dst = src[keep], dst[keep]
        if w is not None:
            w = w[keep]
        new_src = np.concatenate([src, ins_src])
        new_dst = np.concatenate([dst, ins_dst])
        weights = None
        if g.weighted:
            if len(ins_src) and ins_w is None:
                raise ValueError("weighted graph: insert() needs weights")
            ins_w = (ins_w if ins_w is not None
                     else np.zeros(0, dtype=np.float32))
            weights = np.concatenate([w, ins_w])
        return from_edges(new_src, new_dst, n=n, weights=weights)


def _partition_edges(layout: Layout, p: int):
    """(src, dst, w) of partition ``p``'s out-edges from the layout CSR,
    in (src, dst) order."""
    q, n = layout.q, layout.n
    vs, ve = min(p * q, n), min((p + 1) * q, n)
    e0 = int(layout.csr_indptr[vs])
    e1 = int(layout.csr_indptr[ve])
    degs = np.diff(layout.csr_indptr[vs:ve + 1])
    src = np.repeat(np.arange(vs, ve, dtype=np.int64), degs)
    dst = layout.csr_indices[e0:e1].astype(np.int64)
    w = layout.csr_w[e0:e1] if layout.csr_w is not None else None
    return src, dst, w


def _edited_partition(layout: Layout, p: int, delta: DeltaBuffer):
    """New (src, dst, w) arrays for dirty source partition ``p``,
    (src, dst)-sorted — old rows minus deleted/overwritten keys plus the
    partition's inserts."""
    q, n = layout.q, layout.n
    src, dst, w = _partition_edges(layout, p)
    ins_src, ins_dst, ins_w = delta.inserts()
    del_src, del_dst = delta.deletes()
    psel_i = (ins_src // q) == p if q else np.ones(len(ins_src), dtype=bool)
    psel_d = (del_src // q) == p if q else np.ones(len(del_src), dtype=bool)
    ins_src, ins_dst = ins_src[psel_i], ins_dst[psel_i]
    if ins_w is not None:
        ins_w = ins_w[psel_i]
    nk = max(n, 1)
    drop_keys = np.concatenate([ins_src * nk + ins_dst,
                                (del_src[psel_d] * nk + del_dst[psel_d])])
    keep = ~np.isin(src * nk + dst, drop_keys)
    src, dst = src[keep], dst[keep]
    if w is not None:
        w = w[keep]
    new_src = np.concatenate([src, ins_src])
    new_dst = np.concatenate([dst, ins_dst])
    new_w = None
    if layout.weighted:
        if len(ins_src) and ins_w is None:
            raise ValueError("weighted layout: insert() needs weights")
        ins_w = ins_w if ins_w is not None else np.zeros(0, dtype=np.float32)
        new_w = np.concatenate([w, ins_w]).astype(np.float32)
    order = np.lexsort((new_dst, new_src))
    new_src, new_dst = new_src[order], new_dst[order]
    if new_w is not None:
        new_w = new_w[order]
    return new_src, new_dst, new_w


def _clean_block_runs(k: int, dirty: list):
    """Maximal runs ``[g0, g1)`` of consecutive CLEAN gather-block keys
    (``g = dp*k + sp``; a block is dirty iff its source partition
    ``g % k`` is).  Old and new bin offsets stay in lockstep inside a
    run — no dirty block intervenes to change a padded size — so each
    run is one contiguous slice copy."""
    is_dirty = np.zeros(k * k, dtype=bool)
    if dirty:
        d = np.asarray(dirty, dtype=np.int64)
        is_dirty[(np.arange(k, dtype=np.int64)[:, None] * k + d).ravel()] \
            = True
    bnd = np.flatnonzero(np.diff(is_dirty.astype(np.int8))) + 1
    bounds = np.concatenate([[0], bnd, [k * k]])
    return [(int(g0), int(g1))
            for g0, g1 in zip(bounds[:-1], bounds[1:])
            if not is_dirty[g0]]


def apply_delta(layout: Layout, delta: DeltaBuffer) -> Layout:
    """Relayout only the partitions the delta dirties.

    Clean source partitions contribute their CSR rows, their PNG slot row
    (one contiguous copy — slot content is position-independent global
    ids) and their gather-side bin columns (whole padded blocks moved by a
    vectorized index map; ``msg_slot`` values shifted by the per-block PNG
    offset delta) byte-for-byte.  Dirty source partitions re-run the
    ``build_layout`` slot/rank algorithm restricted to their own edges.
    The result is bit-exact equal to a full ``build_layout`` of the edited
    graph with the same ``k`` and tile geometry.
    """
    if delta.k != layout.k or delta.q != layout.q or delta.n != layout.n:
        raise ValueError("delta was buffered against a different partitioning")
    t0 = time.perf_counter()
    k, q, n = layout.k, layout.q, layout.n
    n_pad = layout.n_pad
    msg_tile, edge_tile = layout.msg_tile, layout.edge_tile
    weighted = layout.weighted

    dirty = [int(p) for p in delta.src_partitions()]
    dirty_set = set(dirty)
    clean = [p for p in range(k) if p not in dirty_set]

    # ---- dirty partitions' new edge lists (clean ones stay sliced) ----
    part_rows = {p: _edited_partition(layout, p, delta) for p in dirty}

    # ---- CSR: dirty rows recomputed, clean rows sliced verbatim ----
    degs = np.zeros(n, dtype=np.int64)
    degs[:] = np.diff(layout.csr_indptr[:n + 1])
    seg_ind, seg_w = [], []
    for p in range(k):
        vs, ve = min(p * q, n), min((p + 1) * q, n)
        if p in dirty_set:
            src_p, dst_p, w_p = part_rows[p]
            if ve > vs:
                degs[vs:ve] = np.bincount(src_p - vs, minlength=ve - vs)
            seg_ind.append(dst_p)
            if weighted:
                seg_w.append(w_p)
        else:
            e0, e1 = int(layout.csr_indptr[vs]), int(layout.csr_indptr[ve])
            seg_ind.append(layout.csr_indices[e0:e1])
            if weighted:
                seg_w.append(layout.csr_w[e0:e1])
    m_new = sum(len(s) for s in seg_ind)
    csr_indices = np.concatenate(
        seg_ind or [np.zeros(0, dtype=np.int64)]).astype(np.int32)
    csr_w = None
    if weighted:
        csr_w = np.concatenate(
            seg_w or [np.zeros(0, dtype=np.float32)]).astype(np.float32)
    csr_indptr = np.zeros(n_pad + 2, dtype=np.int64)
    csr_indptr[1:n + 1] = np.cumsum(degs)
    csr_indptr[n + 1:] = m_new

    # ---- scatter side (PNG): per-source-partition slot rows ----
    old_blk_msg_pad = np.diff(layout.png_off)
    blk_msg_pad = old_blk_msg_pad.copy()
    # per-dirty-partition slot structure, in (dp, src, dst) edge order
    dirty_scatter = {}      # p -> dict of per-partition arrays
    for p in dirty:
        src_p, dst_p, w_p = part_rows[p]
        mp = len(src_p)
        dp = dst_p // q if q else np.zeros(mp, dtype=np.int64)
        order = np.argsort(dp, kind="stable")       # -> (dp, src, dst)
        src_s, dst_s, dp_s = src_p[order], dst_p[order], dp[order]
        w_s = w_p[order] if w_p is not None else None
        new_slot = np.ones(mp, dtype=bool)
        if mp > 1:
            same = (src_s[1:] == src_s[:-1]) & (dp_s[1:] == dp_s[:-1])
            new_slot[1:] = ~same
        slot_of_edge = np.cumsum(new_slot) - 1
        slot_src = src_s[new_slot]
        slot_dp = dp_s[new_slot]
        msg_cnt = np.bincount(slot_dp, minlength=k)
        blk_msg_pad[p * k:(p + 1) * k] = _pad_to_array(msg_cnt, msg_tile)
        dirty_scatter[p] = dict(
            src=src_s, dst=dst_s, dp=dp_s, w=w_s,
            slot_of_edge=slot_of_edge, slot_src=slot_src,
            slot_dp=slot_dp, msg_cnt=msg_cnt,
        )
    png_off = np.concatenate([[0], np.cumsum(blk_msg_pad)])
    nm_pad = int(png_off[-1])

    png_src = np.full(nm_pad, n_pad, dtype=np.int32)
    png_src_local = np.zeros(nm_pad, dtype=np.int32)
    for p in clean:
        o0, o1 = int(layout.png_off[p * k]), int(layout.png_off[(p + 1) * k])
        n0 = int(png_off[p * k])
        png_src[n0:n0 + (o1 - o0)] = layout.png_src[o0:o1]
        png_src_local[n0:n0 + (o1 - o0)] = layout.png_src_local[o0:o1]
    for p in dirty:
        ds = dirty_scatter[p]
        nslots = len(ds["slot_src"])
        starts = np.concatenate([[0], np.cumsum(ds["msg_cnt"])])[:-1]
        rank = (np.arange(nslots, dtype=np.int64)
                - np.repeat(starts, ds["msg_cnt"]))
        spos = png_off[p * k + ds["slot_dp"]] + rank
        ds["spos"] = spos
        png_src[spos] = ds["slot_src"]
        png_src_local[spos] = ds["slot_src"] - (ds["slot_src"] // q) * q
    if nm_pad:
        ntm = nm_pad // msg_tile
        tile_blk_m = np.searchsorted(png_off[1:], np.arange(ntm) * msg_tile,
                                     side="right")
        png_tile_part = (tile_blk_m // k).astype(np.int32)
    else:
        png_tile_part = np.zeros(0, dtype=np.int32)

    # ---- gather side (dc_bin): block key g = dp*k + sp ----
    old_blk_edge_pad = np.diff(layout.blk_off)
    blk_edge_pad = old_blk_edge_pad.copy()
    for p in dirty:
        cnt = np.bincount(dirty_scatter[p]["dp"], minlength=k)
        blk_edge_pad[np.arange(k) * k + p] = _pad_to_array(cnt, edge_tile)
        dirty_scatter[p]["edge_cnt"] = cnt
    blk_off = np.concatenate([[0], np.cumsum(blk_edge_pad)])
    ne_pad = int(blk_off[-1])

    msg_slot = np.full(ne_pad, nm_pad, dtype=np.int32)
    edge_dst = np.full(ne_pad, n_pad, dtype=np.int32)
    edge_src_local = np.zeros(ne_pad, dtype=np.int32)
    edge_dst_local = np.zeros(ne_pad, dtype=np.int32)
    edge_valid = np.zeros(ne_pad, dtype=bool)
    edge_w = np.zeros(ne_pad, dtype=np.float32) if weighted else None

    # clean gather blocks: whole padded blocks move in contiguous runs
    # (one memcpy per run — no dirty block inside a run, so old and new
    # offsets differ by a constant).  Content is position-independent
    # except msg_slot, which shifts by its PNG block's offset delta (and
    # pad slots re-point at the new global sentinel)
    old_nm_pad = int(layout.png_off[-1])
    gk_all = np.arange(k * k, dtype=np.int64)
    sblk_all = (gk_all % k) * k + (gk_all // k)
    blk_shift = (png_off[sblk_all]
                 - layout.png_off[sblk_all]).astype(np.int32)
    for g0, g1 in _clean_block_runs(k, dirty):
        o0, o1 = int(layout.blk_off[g0]), int(layout.blk_off[g1])
        if o1 == o0:
            continue
        sl = slice(int(blk_off[g0]), int(blk_off[g0]) + (o1 - o0))
        valid = layout.edge_valid[o0:o1]
        edge_dst[sl] = layout.edge_dst[o0:o1]
        edge_src_local[sl] = layout.edge_src_local[o0:o1]
        edge_dst_local[sl] = layout.edge_dst_local[o0:o1]
        edge_valid[sl] = valid
        if weighted:
            edge_w[sl] = layout.edge_w[o0:o1]
        shift = blk_shift[g0:g1]
        if not shift.any() and nm_pad == old_nm_pad:
            msg_slot[sl] = layout.msg_slot[o0:o1]
        else:
            # pads in the destination already hold the new sentinel
            # (the np.full init): shift only the valid slots, in place
            shift_e = np.repeat(shift, old_blk_edge_pad[g0:g1])
            np.add(layout.msg_slot[o0:o1], shift_e, out=msg_slot[sl],
                   where=valid)
    for p in dirty:
        ds = dirty_scatter[p]
        mp = len(ds["src"])
        if mp == 0:
            continue
        starts = np.concatenate([[0], np.cumsum(ds["edge_cnt"])])[:-1]
        rank = (np.arange(mp, dtype=np.int64)
                - np.repeat(starts, ds["edge_cnt"]))
        epos = blk_off[ds["dp"] * k + p] + rank
        edge_dst[epos] = ds["dst"]
        edge_src_local[epos] = ds["src"] - (ds["src"] // q) * q
        edge_dst_local[epos] = ds["dst"] - ds["dp"] * q
        edge_valid[epos] = True
        if weighted:
            edge_w[epos] = ds["w"]
        msg_slot[epos] = ds["spos"][ds["slot_of_edge"]]

    # ---- per-tile metadata + per-partition constants (cheap, global) ----
    nt = ne_pad // edge_tile
    tile_blk = np.searchsorted(blk_off[1:], np.arange(nt) * edge_tile,
                               side="right")
    tile_dst_part = (tile_blk // k).astype(np.int32)
    tile_src_part = (tile_blk % k).astype(np.int32)
    tile_first = np.ones(nt, dtype=bool)
    tile_first[1:] = tile_dst_part[1:] != tile_dst_part[:-1]
    part_has_tiles = np.zeros(k, dtype=bool)
    part_has_tiles[tile_dst_part] = True

    part_edges = layout.part_edges.copy()
    part_msgs = layout.part_msgs.copy()
    for p in dirty:
        part_edges[p] = len(dirty_scatter[p]["src"])
        part_msgs[p] = len(dirty_scatter[p]["slot_src"])
    deg = np.zeros(n_pad, dtype=np.int64)
    deg[:n] = degs

    new = Layout(
        k=k, q=q, n=n, m=m_new, weighted=weighted,
        png_src=png_src, png_src_local=png_src_local, png_off=png_off,
        png_tile_part=png_tile_part,
        msg_slot=msg_slot, edge_dst=edge_dst,
        edge_src_local=edge_src_local, edge_dst_local=edge_dst_local,
        edge_valid=edge_valid, edge_w=edge_w, blk_off=blk_off,
        edge_tile=edge_tile, msg_tile=msg_tile,
        fold_tile=layout.fold_tile, fold_q=layout.fold_q,
        tile_src_part=tile_src_part, tile_dst_part=tile_dst_part,
        tile_first=tile_first, part_has_tiles=part_has_tiles,
        csr_indptr=csr_indptr, csr_indices=csr_indices, csr_w=csr_w,
        part_edges=part_edges, part_msgs=part_msgs, deg=deg,
    )
    from .. import obs
    if obs.enabled():
        obs.event("delta_apply", dirty_parts=len(dirty), k=k,
                  inserts=delta.num_inserts, deletes=delta.num_deletes,
                  wall_s=time.perf_counter() - t0)
    return new
