from .csr import (Graph, from_edges, rmat, uniform_random, ring, star,
                  grid2d, symmetrize, to_scipy)
from .delta import DeltaBuffer, apply_delta
from .layout import Layout, build_layout

__all__ = ["Graph", "from_edges", "rmat", "uniform_random", "ring", "star",
           "grid2d", "symmetrize", "to_scipy", "Layout", "build_layout",
           "DeltaBuffer", "apply_delta"]
