"""CSR graph container and synthetic graph generators.

GPOP (the paper) stores graphs in CSR/CSC; partitions are index-contiguous
vertex ranges.  This module is the NumPy-side substrate: ingestion,
generators (RMAT as used in the paper's scalability study, uniform random,
and small deterministic graphs for tests), and basic transforms.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class Graph:
    """Directed graph in CSR form (out-edges, sorted by source).

    Attributes:
      indptr:  int64[n + 1]  CSR row pointer.
      indices: int32[m]      destination vertex of each out-edge.
      weights: float32[m] | None  edge weights (None = unweighted).
      n:       number of vertices.
    """

    indptr: np.ndarray
    indices: np.ndarray
    weights: Optional[np.ndarray] = None

    @property
    def n(self) -> int:
        return len(self.indptr) - 1

    @property
    def m(self) -> int:
        return int(self.indptr[-1])

    @property
    def weighted(self) -> bool:
        return self.weights is not None

    def out_degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int64)

    def in_degrees(self) -> np.ndarray:
        return np.bincount(self.indices, minlength=self.n).astype(np.int64)

    def validate(self) -> None:
        assert self.indptr[0] == 0
        assert np.all(np.diff(self.indptr) >= 0)
        assert len(self.indices) == self.m
        if self.m:
            assert self.indices.min() >= 0 and self.indices.max() < self.n
        if self.weights is not None:
            assert len(self.weights) == self.m

    def reverse(self) -> "Graph":
        """CSC view as a CSR graph over reversed edges (in-edges)."""
        order = np.argsort(self.indices, kind="stable")
        src = np.repeat(np.arange(self.n, dtype=np.int32), self.out_degrees())
        new_indptr = np.zeros(self.n + 1, dtype=np.int64)
        np.add.at(new_indptr, self.indices + 1, 1)
        new_indptr = np.cumsum(new_indptr)
        w = self.weights[order] if self.weights is not None else None
        return Graph(new_indptr, src[order], w)


def from_edges(src, dst, n: Optional[int] = None, weights=None,
               dedup: bool = False) -> Graph:
    """Build a CSR graph from an edge list."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if n is None:
        n = int(max(src.max(initial=-1), dst.max(initial=-1)) + 1)
    if dedup and len(src):
        key = src * n + dst
        _, keep = np.unique(key, return_index=True)
        src, dst = src[keep], dst[keep]
        if weights is not None:
            weights = np.asarray(weights)[keep]
    order = np.lexsort((dst, src))
    src, dst = src[order], dst[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, src + 1, 1)
    indptr = np.cumsum(indptr)
    w = None
    if weights is not None:
        w = np.asarray(weights, dtype=np.float32)[order]
    return Graph(indptr, dst.astype(np.int32), w)


def rmat(scale: int, edge_factor: int = 16, seed: int = 0,
         a: float = 0.57, b: float = 0.19, c: float = 0.19,
         weighted: bool = False, dedup: bool = True) -> Graph:
    """RMAT generator (paper §6: default Graph500-style scale-free, deg 16)."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    ab, abc = a + b, a + b + c
    for bit in range(scale):
        r = rng.random(m)
        # quadrant choice per Chakrabarti et al. [9]
        go_right = (r >= a) & (r < ab) | (r >= abc)
        go_down = r >= ab
        src |= go_down.astype(np.int64) << bit
        dst |= go_right.astype(np.int64) << bit
    # permute vertex ids so degree is not index-correlated (standard practice)
    perm = rng.permutation(n)
    src, dst = perm[src], perm[dst]
    w = rng.random(m).astype(np.float32) + 0.01 if weighted else None
    return from_edges(src, dst, n=n, weights=w, dedup=dedup)


def uniform_random(n: int, m: int, seed: int = 0,
                   weighted: bool = False) -> Graph:
    """Erdos-Renyi-ish uniform random directed graph."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    w = rng.random(m).astype(np.float32) + 0.01 if weighted else None
    return from_edges(src, dst, n=n, weights=w, dedup=True)


def ring(n: int, weighted: bool = False) -> Graph:
    src = np.arange(n)
    dst = (src + 1) % n
    w = np.ones(n, dtype=np.float32) if weighted else None
    return from_edges(src, dst, n=n, weights=w)


def star(n: int) -> Graph:
    """Vertex 0 points to all others (max skew for bin-size stress tests)."""
    src = np.zeros(n - 1, dtype=np.int64)
    dst = np.arange(1, n)
    return from_edges(src, dst, n=n)


def grid2d(rows: int, cols: int, weighted: bool = False,
           seed: int = 0) -> Graph:
    """4-neighbor grid — large diameter (stresses frontier algorithms)."""
    idx = np.arange(rows * cols).reshape(rows, cols)
    src, dst = [], []
    src.append(idx[:, :-1].ravel()); dst.append(idx[:, 1:].ravel())
    src.append(idx[:, 1:].ravel()); dst.append(idx[:, :-1].ravel())
    src.append(idx[:-1, :].ravel()); dst.append(idx[1:, :].ravel())
    src.append(idx[1:, :].ravel()); dst.append(idx[:-1, :].ravel())
    src = np.concatenate(src); dst = np.concatenate(dst)
    w = None
    if weighted:
        w = np.random.default_rng(seed).random(len(src)).astype(np.float32) + 0.01
    return from_edges(src, dst, n=rows * cols, weights=w)


def symmetrize(g: Graph) -> Graph:
    """Undirected view: every edge exists in both directions with ONE
    canonical weight per unordered pair (the minimum of the directed
    weights, when both existed).  The result satisfies
    ``d(u, v) == d(v, u)`` exactly — the precondition for the serving
    tier's landmark seeding (:mod:`repro.serve.cache`) and for weakly-
    connected components.  Parallel edges are deduplicated."""
    src = np.repeat(np.arange(g.n, dtype=np.int64), g.out_degrees())
    dst = g.indices.astype(np.int64)
    u = np.minimum(src, dst)
    v = np.maximum(src, dst)
    key = u * g.n + v
    if g.weights is None:
        uniq = np.unique(key)
        wmin = None
    else:
        order = np.argsort(key, kind="stable")
        key_s, w_s = key[order], g.weights[order]
        uniq, start = np.unique(key_s, return_index=True)
        # one canonical weight per unordered pair: min over both
        # directions (and any parallel duplicates)
        wmin = np.minimum.reduceat(w_s, start)
    u2, v2 = uniq // g.n, uniq % g.n
    loop = u2 == v2                       # self loops emitted once
    src2 = np.concatenate([u2, v2[~loop]])
    dst2 = np.concatenate([v2, u2[~loop]])
    w2 = (None if wmin is None
          else np.concatenate([wmin, wmin[~loop]]).astype(np.float32))
    return from_edges(src2, dst2, n=g.n, weights=w2)


def to_scipy(g: Graph):
    import scipy.sparse as sp
    data = g.weights if g.weights is not None else np.ones(g.m, np.float32)
    return sp.csr_matrix((data, g.indices, g.indptr), shape=(g.n, g.n))
