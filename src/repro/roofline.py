"""Roofline-term derivation from AOT-compiled artifacts (EXPERIMENTS.md §Roofline).

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = wire_bytes / (chips * link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()`` (per-device SPMD module).
Collective wire bytes are parsed from the HLO text: every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op contributes
its operand size scaled by the ring-algorithm factor for its replica-group
size N (ag/rs/a2a: (N-1)/N, ar: 2(N-1)/N, cp: 1).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^=]*?\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,\s]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default_n: int) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota [G, N] <= [total]: N participants per group
        return int(m.group(2))
    return default_n


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float            # per device, algorithm-scaled
    raw_bytes: float             # per device, unscaled operand bytes
    counts: dict                 # op -> count

    def as_dict(self):
        return dict(wire_bytes=self.wire_bytes, raw_bytes=self.raw_bytes,
                    counts=self.counts)


def collective_bytes(hlo_text: str, default_group: int) -> CollectiveStats:
    wire = 0.0
    raw = 0.0
    counts: dict = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        type_str, op = m.group(1), m.group(2)
        b = _shape_bytes(type_str)
        if b == 0:
            continue
        n = max(_group_size(line, default_group), 1)
        if op == "all-reduce":
            factor = 2.0 * (n - 1) / n
        elif op in ("all-gather", "reduce-scatter", "all-to-all"):
            factor = (n - 1) / n
        else:                                        # collective-permute
            factor = 1.0
        wire += b * factor
        raw += b
        counts[op] = counts.get(op, 0) + 1
    return CollectiveStats(wire, raw, counts)


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   wire_bytes_per_dev: float) -> dict:
    ct = flops_per_dev / PEAK_FLOPS
    mt = bytes_per_dev / HBM_BW
    lt = wire_bytes_per_dev / LINK_BW
    dom = max(("compute", ct), ("memory", mt), ("collective", lt),
              key=lambda kv: kv[1])
    total = max(ct, mt, lt)
    return dict(compute_s=ct, memory_s=mt, collective_s=lt,
                dominant=dom[0],
                roofline_fraction=(ct / total if total > 0 else 0.0))


def model_flops(cfg, seq: int, batch: int, kind: str) -> float:
    """MODEL_FLOPS: 6*N*D train, 2*N*D forward (D = tokens processed)."""
    n = cfg.active_param_count()
    tokens = seq * batch if kind != "decode" else batch
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens
