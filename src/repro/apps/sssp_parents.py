"""SSSP with parent tracking via the packed (distance, parent) min-monoid.

The paper's Alg. 8 tracks distances only; production SSSP wants the shortest
-path tree.  A lexicographic uint64 lattice — (f32 distance bits << 32) |
parent id — keeps the whole fold a pure ``min``, so the lock-free gather
contract is untouched.  Requires x64 (see monoid.min_with_payload).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core import monoid as M
from ..core.engine import Engine
from ..core.program import VertexProgram


def sssp_parents_program() -> VertexProgram:
    mono = M.min_with_payload()

    def scatter_fn(state):
        # message key = my distance (weight added en route), payload = my id
        return M.pack_key_payload(state["dist"], state["vid"])

    def apply_weight(vals, w):
        key, payload = M.unpack_key_payload(vals)
        return M.pack_key_payload(key + w, payload)

    def apply_fn(state, acc, touched, it):
        key, parent = M.unpack_key_payload(acc)
        better = touched & (key < state["dist"])
        dist = jnp.where(better, key, state["dist"])
        par = jnp.where(better, parent.astype(jnp.int32), state["parent"])
        return dict(state, dist=dist, parent=par), better

    return VertexProgram(name="sssp_parents", monoid=mono,
                         scatter_fn=scatter_fn, apply_fn=apply_fn,
                         apply_weight=apply_weight)


def sssp_with_parents(layout, source: int, mode: str = "hybrid",
                      backend=None, engine: Engine = None,
                      max_iters: int = None):
    assert layout.weighted, "needs edge weights"
    with jax.experimental.enable_x64():
        n_pad = layout.n_pad
        dist = jnp.full((n_pad,), jnp.inf, jnp.float32).at[source].set(0.0)
        parent = jnp.full((n_pad,), -1, jnp.int32).at[source].set(source)
        vid = jnp.arange(n_pad, dtype=jnp.uint32)
        frontier = np.zeros(n_pad, bool)
        frontier[source] = True
        eng = engine if engine is not None else Engine(
            layout, sssp_parents_program(), mode=mode, backend=backend)
        state, _, stats = eng.run(
            {"dist": dist, "parent": parent, "vid": vid}, frontier,
            max_iters=max_iters or n_pad)
        return {"dist": np.asarray(state["dist"])[:layout.n],
                "parent": np.asarray(state["parent"])[:layout.n],
                "stats": stats}


def sssp_parents_multi(layout, sources, engine: Engine = None,
                       max_iters: int = None):
    """Batched multi-source SSSP with parent tracking (uint64 packed
    monoid, so the gather falls back to the ref kernels — still one fused
    vmapped invocation per iteration).  Row ``i`` belongs to
    ``sources[i]``.  A :class:`repro.dist.engine.DistEngine` works as
    ``engine`` too; its bf16 wire never engages for this monoid (uint64,
    not f32), so distributed results stay exact."""
    assert layout.weighted, "needs edge weights"
    with jax.experimental.enable_x64():
        sources = np.atleast_1d(np.asarray(sources, dtype=np.int64))
        B, n_pad = len(sources), layout.n_pad
        src = jnp.asarray(sources, jnp.int32)
        lanes = jnp.arange(B)
        dist = jnp.full((B, n_pad), jnp.inf, jnp.float32) \
            .at[lanes, src].set(0.0)
        parent = jnp.full((B, n_pad), -1, jnp.int32).at[lanes, src].set(src)
        vid = jnp.broadcast_to(jnp.arange(n_pad, dtype=jnp.uint32),
                               (B, n_pad))
        frontier = np.zeros((B, n_pad), bool)
        frontier[np.arange(B), sources] = True
        eng = engine if engine is not None else Engine(
            layout, sssp_parents_program(), mode="dc")
        states, _, stats = eng.run_batched(
            {"dist": dist, "parent": parent, "vid": vid}, frontier,
            max_iters=max_iters or n_pad)
        return {"dist": np.asarray(states["dist"])[:, :layout.n],
                "parent": np.asarray(states["parent"])[:, :layout.n],
                "stats": stats}
