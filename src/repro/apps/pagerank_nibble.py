"""PageRank-Nibble (paper §4.1 cites it with Nibble as needing selective
frontier continuity; Andersen-Chung-Lang approximate personalized PageRank).

Push-free formulation on PPM: residual r diffuses, solution p accumulates:
  p += alpha * r;   r' = (1-alpha)/2 * (r/deg pushed to neighbors + r kept)
frontier = {v : r(v) >= eps * deg(v)} — selective continuity keeps vertices
with large residual active regardless of incoming updates.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core import monoid as M
from ..core.engine import Engine
from ..core.program import VertexProgram


def pagerank_nibble_program(alpha: float, eps: float) -> VertexProgram:
    def scatter_fn(state):
        # push half of the non-retained residual along out-edges
        share = (1.0 - alpha) * 0.5 * state["r"]
        return jnp.where(state["deg"] > 0, share / state["deg"], 0.0)

    def init_fn(state, it):
        p = state["p"] + alpha * state["r"]
        r = (1.0 - alpha) * 0.5 * state["r"]      # lazy half stays local
        keep = r >= eps * state["deg"]
        return dict(state, p=p, r=r), keep

    def apply_fn(state, acc, touched, it):
        r = state["r"] + acc
        return dict(state, r=r), r >= eps * state["deg"]

    def filter_fn(state, it):
        return state, state["r"] >= eps * state["deg"]

    return VertexProgram(name="pagerank_nibble",
                         monoid=M.add(jnp.float32),
                         scatter_fn=scatter_fn, apply_fn=apply_fn,
                         init_fn=init_fn, filter_fn=filter_fn)


def pagerank_nibble(layout, seeds, alpha: float = 0.15, eps: float = 1e-5,
                    max_iters: int = 200, mode: str = "hybrid"):
    n_pad = layout.n_pad
    seeds = np.atleast_1d(np.asarray(seeds))
    program = pagerank_nibble_program(alpha, eps)
    r = jnp.zeros((n_pad,), jnp.float32).at[seeds].set(1.0 / len(seeds))
    state = {"p": jnp.zeros((n_pad,), jnp.float32), "r": r,
             "deg": jnp.asarray(layout.deg.astype(np.float32))}
    frontier = np.zeros(n_pad, bool)
    frontier[seeds] = True
    eng = Engine(layout, program, mode=mode)
    state, _, stats = eng.run(state, frontier, max_iters=max_iters)
    return {"ppr": np.asarray(state["p"])[:layout.n],
            "residual": np.asarray(state["r"])[:layout.n], "stats": stats}
