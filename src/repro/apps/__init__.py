from .bfs import (bfs, bfs_multi, bfs_program, bfs_seeded_multi,
                  bfs_seeded_pack, bfs_seeded_program)
from .pagerank import pagerank, pagerank_program
from .sssp import sssp, sssp_multi, sssp_program
from .cc import connected_components, cc_program
from .nibble import nibble, nibble_program
from .sssp_parents import (sssp_parents_multi, sssp_parents_program,
                           sssp_with_parents)
from .heat_kernel import heat_kernel_pr, heat_kernel_program
from .pagerank_nibble import pagerank_nibble, pagerank_nibble_program

__all__ = [
    "bfs", "bfs_multi", "bfs_program", "bfs_seeded_multi",
    "bfs_seeded_pack", "bfs_seeded_program", "pagerank", "pagerank_program",
    "sssp", "sssp_multi", "sssp_program", "connected_components",
    "cc_program", "nibble", "nibble_program", "sssp_with_parents",
    "sssp_parents_multi", "sssp_parents_program", "heat_kernel_pr",
    "heat_kernel_program", "pagerank_nibble", "pagerank_nibble_program",
]
