"""Single-Source Shortest Path, Bellman-Ford style (paper Alg. 8).

scatterFunc -> distance;  applyWeight -> val + wt;  gatherFunc -> relax
(min-monoid), activate on improvement;  initFunc -> false.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core import monoid as M
from ..core.engine import Engine
from ..core.program import VertexProgram

INF = np.float32(np.inf)


def sssp_program() -> VertexProgram:
    def scatter_fn(state):
        return state["dist"]

    def apply_fn(state, acc, touched, it):
        better = touched & (acc < state["dist"])
        dist = jnp.where(better, acc, state["dist"])
        return dict(state, dist=dist), better

    def apply_weight(vals, w):
        return vals + w

    return VertexProgram(name="sssp", monoid=M.min_(jnp.float32),
                         scatter_fn=scatter_fn, apply_fn=apply_fn,
                         apply_weight=apply_weight)


def sssp(layout, source: int, mode: str = "hybrid",
         use_pallas: bool = None, max_iters: int = None,
         backend=None, engine: Engine = None):
    assert layout.weighted, "SSSP needs an edge-weighted graph"
    n_pad = layout.n_pad
    dist = jnp.full((n_pad,), INF, jnp.float32).at[source].set(0.0)
    frontier = np.zeros(n_pad, bool)
    frontier[source] = True
    eng = engine if engine is not None else Engine(
        layout, sssp_program(), mode=mode, backend=backend,
        use_pallas=use_pallas)
    state, _, stats = eng.run({"dist": dist}, frontier,
                              max_iters=max_iters or n_pad)
    return {"dist": np.asarray(state["dist"])[:layout.n], "stats": stats}


def sssp_multi(layout, sources, backend=None, engine: Engine = None,
               max_iters: int = None, dist0=None, frontier0=None):
    """Batched multi-source SSSP: one fused :meth:`Engine.run_batched`
    invocation relaxes ``len(sources)`` queries together, bit-exact with
    per-source :func:`sssp` calls.  Row ``i`` belongs to ``sources[i]``.
    ``engine`` may be a :class:`repro.dist.engine.DistEngine` to relax the
    batch across the device mesh (same vertex space: ``D*nv == n_pad``);
    note a dist engine built with ``wire_bf16=True`` rounds f32 distances
    to bf16 on the wire — batched and sequential runs under the SAME wire
    config still match bit-for-bit.

    ``dist0`` / ``frontier0`` are the warm-start entry (landmark
    seeding): per-lane ``[B, n_pad]`` initial distances and frontiers.
    Bellman-Ford relaxation converges to the exact per-source fixpoint
    from ANY ``dist0`` that upper-bounds the true distances (with
    ``dist0[i, sources[i]] = 0``), provided ``frontier0`` covers every
    vertex holding a finite bound — see :mod:`repro.serve.cache` for the
    seeding construction and the correctness argument.  Lanes may mix
    seeded and cold initializations."""
    assert layout.weighted, "SSSP needs an edge-weighted graph"
    sources = np.atleast_1d(np.asarray(sources, dtype=np.int64))
    B, n_pad = len(sources), layout.n_pad
    src = jnp.asarray(sources, jnp.int32)
    if dist0 is None:
        dist = jnp.full((B, n_pad), INF, jnp.float32) \
            .at[jnp.arange(B), src].set(0.0)
    else:
        dist = jnp.asarray(dist0, jnp.float32)
    if frontier0 is None:
        frontier = np.zeros((B, n_pad), bool)
        frontier[np.arange(B), sources] = True
    else:
        frontier = np.asarray(frontier0, bool)
    eng = engine if engine is not None else Engine(
        layout, sssp_program(), mode="dc", backend=backend)
    states, _, stats = eng.run_batched({"dist": dist}, frontier,
                                       max_iters=max_iters or n_pad)
    return {"dist": np.asarray(states["dist"])[:, :layout.n],
            "stats": stats}
