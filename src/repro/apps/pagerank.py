"""PageRank (paper Alg. 6).

scatterFunc -> rank/deg;  initFunc -> zero the rank, stay active;
gatherFunc -> accumulate;  filterFunc -> damping.  All vertices stay active
every iteration, so the engine runs the fully-fused DC path (paper §6.2.2:
"PageRank always uses DC mode").
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core import monoid as M
from ..core.engine import Engine
from ..core.program import VertexProgram


def pagerank_program(n: int, damping: float = 0.85) -> VertexProgram:
    base = (1.0 - damping) / n

    def scatter_fn(state):
        return jnp.where(state["deg"] > 0, state["pr"] / state["deg"], 0.0)

    def init_fn(state, it):
        return dict(state, pr=jnp.zeros_like(state["pr"])), \
            jnp.ones(state["pr"].shape, jnp.bool_)

    def apply_fn(state, acc, touched, it):
        return dict(state, pr=state["pr"] + acc), jnp.ones_like(touched)

    def filter_fn(state, it):
        return dict(state, pr=base + damping * state["pr"]), \
            jnp.ones(state["pr"].shape, jnp.bool_)

    return VertexProgram(name="pagerank", monoid=M.add(jnp.float32),
                         scatter_fn=scatter_fn, apply_fn=apply_fn,
                         init_fn=init_fn, filter_fn=filter_fn)


def pagerank(layout, iters: int = 10, damping: float = 0.85,
             mode: str = "dc", fused: bool = True,
             use_pallas: bool = None, backend=None,
             engine: Engine = None, pr0=None):
    """``pr0=`` is the residual-restart path for dynamic graphs: pass the
    previous layout's converged ``[n]`` (or ``[n_pad]``) vector after a
    small delta and the damping contraction shrinks the *residual* —
    which a warm start leaves small — by ``damping`` each sweep, so the
    same fixpoint is reached in far fewer iterations than from the
    uniform cold init (the iteration itself is unchanged and the
    fixpoint is unique, so warm vs cold agree to the tolerance the
    iteration count buys)."""
    n_pad = layout.n_pad
    if pr0 is None:
        pr = jnp.full((n_pad,), 1.0 / layout.n, jnp.float32)
    else:
        warm = np.asarray(pr0, np.float32).reshape(-1)
        pr = np.full(n_pad, 1.0 / layout.n, np.float32)
        pr[:min(warm.size, n_pad)] = warm[:n_pad]
        pr = jnp.asarray(pr)
    deg = jnp.asarray(layout.deg.astype(np.float32))
    state0 = {"pr": pr, "deg": deg}
    frontier = np.zeros(n_pad, bool)
    frontier[:layout.n] = True
    eng = engine if engine is not None else Engine(
        layout, pagerank_program(layout.n, damping), mode=mode,
        backend=backend, use_pallas=use_pallas)
    if fused:
        state, _ = eng.run_fused(state0, frontier, iters)
        stats = []
    else:
        state, _, stats = eng.run(state0, frontier, max_iters=iters,
                                  until_empty=False)
    return {"pr": np.asarray(state["pr"])[:layout.n], "stats": stats}
