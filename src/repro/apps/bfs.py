"""Breadth-First Search (paper Alg. 5).

scatterFunc -> own id;  initFunc -> false (frontier rebuilt);
gatherFunc -> first-visit parent update (min-monoid: lowest-id parent wins,
a deterministic valid BFS tree);  filterFunc -> true.

:func:`bfs_seeded_program` is the warm-startable variant: the stock
program derives levels from the iteration counter (``level = it + 1``),
which is only correct from a cold frontier, so the serving tier's
landmark-seeded queries instead run a packed lexicographic
``(level, parent)`` min-monoid relaxation whose cold run is
bit-identical to stock BFS (see its docstring) and whose warm run is
exactly correct from any upper-bound seed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core import monoid as M
from ..core.engine import Engine
from ..core.program import VertexProgram


def bfs_program() -> VertexProgram:
    def scatter_fn(state):
        return state["vid"]

    def apply_fn(state, acc, touched, it):
        unvisited = state["parent"] < 0
        hit = touched & unvisited
        parent = jnp.where(hit, acc.astype(jnp.int32), state["parent"])
        level = jnp.where(hit, it + 1, state["level"])
        return dict(state, parent=parent, level=level), hit

    return VertexProgram(name="bfs", monoid=M.min_(jnp.uint32),
                         scatter_fn=scatter_fn, apply_fn=apply_fn)


def bfs(layout, source: int, mode: str = "hybrid",
        use_pallas: bool = None, bw_ratio: float = 2.0,
        backend=None, engine: Engine = None, max_iters: int = None):
    n_pad = layout.n_pad
    parent = jnp.full((n_pad,), -1, jnp.int32).at[source].set(source)
    level = jnp.full((n_pad,), -1, jnp.int32).at[source].set(0)
    vid = jnp.arange(n_pad, dtype=jnp.uint32)
    frontier = np.zeros(n_pad, bool)
    frontier[source] = True
    eng = engine if engine is not None else Engine(
        layout, bfs_program(), mode=mode, backend=backend,
        use_pallas=use_pallas, bw_ratio=bw_ratio)
    state, _, stats = eng.run({"parent": parent, "level": level, "vid": vid},
                              frontier, max_iters=max_iters or n_pad)
    return {"parent": np.asarray(state["parent"])[:layout.n],
            "level": np.asarray(state["level"])[:layout.n],
            "stats": stats}


def bfs_multi(layout, sources, backend=None, engine: Engine = None,
              max_iters: int = None):
    """Batched multi-source BFS: one fused :meth:`Engine.run_batched`
    invocation answers ``len(sources)`` queries, bit-exact with per-source
    :func:`bfs` calls.  Row ``i`` of every result array belongs to
    ``sources[i]``.  ``engine`` may also be a
    :class:`repro.dist.engine.DistEngine` over a sharding of this layout
    (``D*nv == n_pad``: the global vertex space is identical), in which
    case the batch advances across the device mesh."""
    sources = np.atleast_1d(np.asarray(sources, dtype=np.int64))
    B, n_pad = len(sources), layout.n_pad
    lanes = jnp.arange(B)
    src = jnp.asarray(sources, jnp.int32)
    parent = jnp.full((B, n_pad), -1, jnp.int32).at[lanes, src].set(src)
    level = jnp.full((B, n_pad), -1, jnp.int32).at[lanes, src].set(0)
    vid = jnp.broadcast_to(jnp.arange(n_pad, dtype=jnp.uint32), (B, n_pad))
    frontier = np.zeros((B, n_pad), bool)
    frontier[np.arange(B), sources] = True
    eng = engine if engine is not None else Engine(
        layout, bfs_program(), mode="dc", backend=backend)
    states, _, stats = eng.run_batched(
        {"parent": parent, "level": level, "vid": vid}, frontier,
        max_iters=max_iters or n_pad)
    return {"parent": np.asarray(states["parent"])[:, :layout.n],
            "level": np.asarray(states["level"])[:, :layout.n],
            "stats": stats}


# ----------------------------------------------------------------------
# warm-startable BFS (landmark seeding)
# ----------------------------------------------------------------------

#: payload sentinel for "level known (or bounded), parent unknown" seeds —
#: any real parent message with an equal key beats it lexicographically
PARENT_SENTINEL = np.uint32(0xFFFFFFFF)


def bfs_seeded_program() -> VertexProgram:
    """BFS as a packed lexicographic ``(level, parent)`` relaxation.

    State holds one uint64 per vertex: ``(f32 level bits << 32) | parent``
    (:func:`repro.core.monoid.pack_key_payload`; unvisited = ``(inf,
    PARENT_SENTINEL)``).  Scatter sends ``(level + 1, own id)`` (identity
    for unvisited vertices, so they never pollute the fold); apply keeps
    the packed minimum and activates on any packed improvement.

    Cold equivalence with :func:`bfs_program` (bit-exact levels AND
    parents): from a cold frontier, a vertex at true level ``t`` first
    receives messages at iteration ``t-1``, all of them from in-neighbors
    at level ``t-1`` (deeper neighbors are still unvisited and scatter
    the identity; shallower ones send larger keys which lose the fold),
    so the packed min is ``(t, min id of the level-(t-1) in-neighbors)``
    — exactly the first-visit update of the stock program.

    Warm correctness: the packed order is a monotone min-monoid, so
    relaxation from any *upper-bound* initialization converges to the
    same least fixpoint as the cold run (see
    :mod:`repro.serve.cache` for the full argument).  Requires x64
    (uint64 packing) — run inside ``jax.experimental.enable_x64()``.
    """
    mono = M.min_with_payload()

    def scatter_fn(state):
        key, _ = M.unpack_key_payload(state["best"])
        msg = M.pack_key_payload(key + 1.0, state["vid"])
        return jnp.where(jnp.isfinite(key), msg, mono.identity)

    def apply_fn(state, acc, touched, it):
        better = touched & (acc < state["best"])
        best = jnp.where(better, acc, state["best"])
        return dict(state, best=best), better

    return VertexProgram(name="bfs_seeded", monoid=mono,
                         scatter_fn=scatter_fn, apply_fn=apply_fn)


def bfs_seeded_pack(level, parent):
    """Pack int level / parent vectors (−1 = unvisited) into the seeded
    program's uint64 state.  Needs an active x64 context."""
    level = jnp.asarray(level)
    visited = level >= 0
    key = jnp.where(visited, level.astype(jnp.float32), jnp.inf)
    payload = jnp.where(visited, jnp.asarray(parent).astype(jnp.uint32),
                        PARENT_SENTINEL)
    return M.pack_key_payload(key, payload)


def bfs_seeded_multi(layout, sources, engine: Engine = None,
                     max_iters: int = None, seeds=None, frontiers=None,
                     seed_levels=None, seed_parents=None):
    """Batched warm-startable BFS.  Without seeds this is a cold run
    of :func:`bfs_seeded_program`, bit-exact with :func:`bfs_multi`.

    ``seeds`` is an optional ``[B, n_pad]`` uint64 array of packed
    ``(level upper bound, parent)`` initializations (see
    :func:`bfs_seeded_pack`); lanes may mix seeded and cold entries.
    ``seed_levels`` / ``seed_parents`` (``[B, n_pad]`` int, −1 =
    unvisited / unknown parent) are the unpacked convenience form —
    packing needs an active x64 context, which only exists inside this
    function, so callers holding plain int vectors pass them here
    instead of calling :func:`bfs_seeded_pack` themselves.
    ``frontiers`` (``[B, n_pad]`` bool) must cover every vertex carrying
    a finite seed so stale bounds get relaxed; it defaults to the cold
    one-hot sources."""
    sources = np.atleast_1d(np.asarray(sources, dtype=np.int64))
    B, n_pad = len(sources), layout.n_pad
    with jax.experimental.enable_x64():
        src = jnp.asarray(sources, jnp.int32)
        lanes = jnp.arange(B)
        if seeds is not None:
            best = jnp.asarray(seeds, jnp.uint64)
        elif seed_levels is not None:
            best = bfs_seeded_pack(jnp.asarray(seed_levels),
                                   jnp.asarray(seed_parents))
        else:
            level = jnp.full((B, n_pad), -1, jnp.int32).at[lanes, src].set(0)
            best = bfs_seeded_pack(level, jnp.broadcast_to(src[:, None],
                                                           (B, n_pad)))
        vid = jnp.broadcast_to(jnp.arange(n_pad, dtype=jnp.uint32),
                               (B, n_pad))
        if frontiers is None:
            frontiers = np.zeros((B, n_pad), bool)
            frontiers[np.arange(B), sources] = True
        eng = engine if engine is not None else Engine(
            layout, bfs_seeded_program(), mode="dc")
        states, _, stats = eng.run_batched({"best": best, "vid": vid},
                                           frontiers,
                                           max_iters=max_iters or n_pad)
        key, payload = M.unpack_key_payload(states["best"])
        visited = jnp.isfinite(key)
        level = jnp.where(visited, key.astype(jnp.int32), -1)
        parent = jnp.where(visited, payload.astype(jnp.int32), -1)
        return {"parent": np.asarray(parent)[:, :layout.n],
                "level": np.asarray(level)[:, :layout.n],
                "stats": stats}
