"""Breadth-First Search (paper Alg. 5).

scatterFunc -> own id;  initFunc -> false (frontier rebuilt);
gatherFunc -> first-visit parent update (min-monoid: lowest-id parent wins,
a deterministic valid BFS tree);  filterFunc -> true.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core import monoid as M
from ..core.engine import Engine
from ..core.program import VertexProgram


def bfs_program() -> VertexProgram:
    def scatter_fn(state):
        return state["vid"]

    def apply_fn(state, acc, touched, it):
        unvisited = state["parent"] < 0
        hit = touched & unvisited
        parent = jnp.where(hit, acc.astype(jnp.int32), state["parent"])
        level = jnp.where(hit, it + 1, state["level"])
        return dict(state, parent=parent, level=level), hit

    return VertexProgram(name="bfs", monoid=M.min_(jnp.uint32),
                         scatter_fn=scatter_fn, apply_fn=apply_fn)


def bfs(layout, source: int, mode: str = "hybrid",
        use_pallas: bool = None, bw_ratio: float = 2.0,
        backend=None, engine: Engine = None, max_iters: int = None):
    n_pad = layout.n_pad
    parent = jnp.full((n_pad,), -1, jnp.int32).at[source].set(source)
    level = jnp.full((n_pad,), -1, jnp.int32).at[source].set(0)
    vid = jnp.arange(n_pad, dtype=jnp.uint32)
    frontier = np.zeros(n_pad, bool)
    frontier[source] = True
    eng = engine if engine is not None else Engine(
        layout, bfs_program(), mode=mode, backend=backend,
        use_pallas=use_pallas, bw_ratio=bw_ratio)
    state, _, stats = eng.run({"parent": parent, "level": level, "vid": vid},
                              frontier, max_iters=max_iters or n_pad)
    return {"parent": np.asarray(state["parent"])[:layout.n],
            "level": np.asarray(state["level"])[:layout.n],
            "stats": stats}


def bfs_multi(layout, sources, backend=None, engine: Engine = None,
              max_iters: int = None):
    """Batched multi-source BFS: one fused :meth:`Engine.run_batched`
    invocation answers ``len(sources)`` queries, bit-exact with per-source
    :func:`bfs` calls.  Row ``i`` of every result array belongs to
    ``sources[i]``.  ``engine`` may also be a
    :class:`repro.dist.engine.DistEngine` over a sharding of this layout
    (``D*nv == n_pad``: the global vertex space is identical), in which
    case the batch advances across the device mesh."""
    sources = np.atleast_1d(np.asarray(sources, dtype=np.int64))
    B, n_pad = len(sources), layout.n_pad
    lanes = jnp.arange(B)
    src = jnp.asarray(sources, jnp.int32)
    parent = jnp.full((B, n_pad), -1, jnp.int32).at[lanes, src].set(src)
    level = jnp.full((B, n_pad), -1, jnp.int32).at[lanes, src].set(0)
    vid = jnp.broadcast_to(jnp.arange(n_pad, dtype=jnp.uint32), (B, n_pad))
    frontier = np.zeros((B, n_pad), bool)
    frontier[np.arange(B), sources] = True
    eng = engine if engine is not None else Engine(
        layout, bfs_program(), mode="dc", backend=backend)
    states, _, stats = eng.run_batched(
        {"parent": parent, "level": level, "vid": vid}, frontier,
        max_iters=max_iters or n_pad)
    return {"parent": np.asarray(states["parent"])[:, :layout.n],
            "level": np.asarray(states["level"])[:, :layout.n],
            "stats": stats}
