"""Connected components via label propagation (paper Alg. 7, §5).

labels start as vertex ids; scatterFunc -> label; gatherFunc (compLabel) ->
keep the minimum label, activate on change.  On symmetrized graphs this
converges to weakly-connected components.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core import monoid as M
from ..core.engine import Engine
from ..core.program import VertexProgram


def cc_program() -> VertexProgram:
    def scatter_fn(state):
        return state["label"]

    def apply_fn(state, acc, touched, it):
        better = touched & (acc < state["label"])
        label = jnp.where(better, acc, state["label"])
        return dict(state, label=label), better

    return VertexProgram(name="cc", monoid=M.min_(jnp.uint32),
                         scatter_fn=scatter_fn, apply_fn=apply_fn)


def connected_components(layout, mode: str = "hybrid",
                         use_pallas: bool = None,
                         backend=None, engine: Engine = None):
    n_pad = layout.n_pad
    label = jnp.arange(n_pad, dtype=jnp.uint32)
    frontier = np.zeros(n_pad, bool)
    frontier[:layout.n] = True
    eng = engine if engine is not None else Engine(
        layout, cc_program(), mode=mode, backend=backend,
        use_pallas=use_pallas)
    state, _, stats = eng.run({"label": label}, frontier, max_iters=n_pad)
    return {"label": np.asarray(state["label"])[:layout.n], "stats": stats}
