"""Connected components via label propagation (paper Alg. 7, §5).

labels start as vertex ids; scatterFunc -> label; gatherFunc (compLabel) ->
keep the minimum label, activate on change.  On symmetrized graphs this
converges to weakly-connected components.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core import monoid as M
from ..core.engine import Engine
from ..core.program import VertexProgram


def cc_program() -> VertexProgram:
    def scatter_fn(state):
        return state["label"]

    def apply_fn(state, acc, touched, it):
        better = touched & (acc < state["label"])
        label = jnp.where(better, acc, state["label"])
        return dict(state, label=label), better

    return VertexProgram(name="cc", monoid=M.min_(jnp.uint32),
                         scatter_fn=scatter_fn, apply_fn=apply_fn)


def connected_components(layout, mode: str = "hybrid",
                         use_pallas: bool = None,
                         backend=None, engine: Engine = None,
                         resume_labels=None, touched=None):
    """Labels per vertex; ``resume_labels=``/``touched=`` is the
    incremental path after an insertion-only graph delta: the old
    converged ``[n]`` labels resume with the delta-touched vertices
    (``DeltaBuffer.touched()``) as the initial frontier.  Min-monoid
    label propagation from a converged upper bound is exact — see
    :meth:`repro.core.engine.Engine.run` — so the result is bit-identical
    to a cold run on the new layout.  (Deletions can split components,
    which would need labels to *rise*: run cold.)"""
    n_pad = layout.n_pad
    eng = engine if engine is not None else Engine(
        layout, cc_program(), mode=mode, backend=backend,
        use_pallas=use_pallas)
    if (resume_labels is None) != (touched is None):
        raise ValueError("resume_labels= and touched= go together")
    if resume_labels is not None:
        label = np.arange(n_pad, dtype=np.uint32)   # pads keep their ids
        label[:layout.n] = np.asarray(resume_labels, np.uint32)[:layout.n]
        from ..graph.delta import DeltaBuffer
        if isinstance(touched, DeltaBuffer):
            if touched.num_deletes:
                raise ValueError(
                    "connected_components(resume_labels=) is exact only "
                    "for insertion-only deltas; deletions can split "
                    "components (labels would need to rise) — run cold "
                    "on the new layout instead")
            touched = touched.touched()
        t = np.asarray(touched, bool).reshape(-1)    # [n] or [n_pad]
        frontier = np.zeros(n_pad, bool)
        frontier[:min(t.size, n_pad)] = t[:n_pad]
        frontier[layout.n:] = False
        state, _, stats = eng.run(
            resume_from={"label": jnp.asarray(label)}, touched=frontier,
            max_iters=n_pad)
    else:
        label = jnp.arange(n_pad, dtype=jnp.uint32)
        frontier = np.zeros(n_pad, bool)
        frontier[:layout.n] = True
        state, _, stats = eng.run({"label": label}, frontier,
                                  max_iters=n_pad)
    return {"label": np.asarray(state["label"])[:layout.n], "stats": stats}
