"""Parallel Nibble (paper Alg. 3/4) — seeded random-walk probability mass.

This is the paper's showcase for *selective frontier continuity*:
initFunc halves the vertex's probability and lets it stay active iff the
retained mass is still above the eps*deg threshold, independently of whether
the Gather phase touches it again.

One iteration:  p(v) <- p(v)/2 + sum_{u->v, u active} p(u)/(2 deg(u)),
with the frontier = {v : p(v) >= eps*deg(v)}.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core import monoid as M
from ..core.engine import Engine
from ..core.program import VertexProgram


def nibble_program(eps: float) -> VertexProgram:
    def scatter_fn(state):
        return jnp.where(state["deg"] > 0,
                         state["pr"] / (2.0 * state["deg"]), 0.0)

    def init_fn(state, it):
        pr = state["pr"] * 0.5
        return dict(state, pr=pr), pr >= eps * state["deg"]

    def apply_fn(state, acc, touched, it):
        return dict(state, pr=state["pr"] + acc), jnp.ones_like(touched)

    def filter_fn(state, it):
        return state, state["pr"] >= eps * state["deg"]

    return VertexProgram(name="nibble", monoid=M.add(jnp.float32),
                         scatter_fn=scatter_fn, apply_fn=apply_fn,
                         init_fn=init_fn, filter_fn=filter_fn)


def nibble(layout, seeds, eps: float = 1e-4, max_iters: int = 100,
           mode: str = "hybrid", use_pallas: bool = None,
           backend=None, engine: Engine = None):
    n_pad = layout.n_pad
    seeds = np.atleast_1d(np.asarray(seeds))
    pr = jnp.zeros((n_pad,), jnp.float32).at[seeds].set(1.0 / len(seeds))
    deg = jnp.asarray(layout.deg.astype(np.float32))
    frontier = np.zeros(n_pad, bool)
    frontier[seeds] = True
    eng = engine if engine is not None else Engine(
        layout, nibble_program(eps), mode=mode, backend=backend,
        use_pallas=use_pallas)
    state, _, stats = eng.run({"pr": pr, "deg": deg}, frontier,
                              max_iters=max_iters)
    return {"pr": np.asarray(state["pr"])[:layout.n], "stats": stats}
