"""Heat-Kernel PageRank (paper §4.1 cites it as a selective-continuity
application, after Shun et al. [29]).

hkpr(v) = sum_k e^{-t} t^k / k! * P^k(seed)(v), truncated at K terms.
Implemented as K diffusion iterations where the iteration index drives the
coefficient — showcasing the ``it`` argument of the GPOP API and initFunc's
selective continuity (vertices keep diffusing while their residual mass is
above eps, independent of incoming updates).

State: sol (accumulated solution), res (residual mass being diffused).
Iteration k:  sol += res * (weight of staying);  res' = P^T res * t/(k+1).
"""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

from ..core import monoid as M
from ..core.engine import Engine
from ..core.program import VertexProgram


def heat_kernel_program(t: float, eps: float) -> VertexProgram:
    def scatter_fn(state):
        return jnp.where(state["deg"] > 0,
                         state["res"] / state["deg"], 0.0)

    def init_fn(state, it):
        # bank the local coefficient share, keep diffusing if mass remains
        k = it.astype(jnp.float32)
        sol = state["sol"] + state["res"]
        res = jnp.zeros_like(state["res"])
        return dict(state, sol=sol, res=res), \
            jnp.zeros(state["res"].shape, jnp.bool_)

    def apply_fn(state, acc, touched, it):
        k = it.astype(jnp.float32)
        res = state["res"] + acc * (t / (k + 1.0))
        return dict(state, res=res), res > eps * state["deg"]

    return VertexProgram(name="heat_kernel", monoid=M.add(jnp.float32),
                         scatter_fn=scatter_fn, apply_fn=apply_fn,
                         init_fn=init_fn)


def heat_kernel_pr(layout, seeds, t: float = 5.0, eps: float = 1e-5,
                   max_terms: int = 30, mode: str = "hybrid"):
    n_pad = layout.n_pad
    seeds = np.atleast_1d(np.asarray(seeds))
    program = heat_kernel_program(t, eps)
    res = jnp.zeros((n_pad,), jnp.float32).at[seeds].set(1.0 / len(seeds))
    state = {"sol": jnp.zeros((n_pad,), jnp.float32), "res": res,
             "deg": jnp.asarray(layout.deg.astype(np.float32))}
    frontier = np.zeros(n_pad, bool)
    frontier[seeds] = True
    eng = Engine(layout, program, mode=mode)
    state, _, stats = eng.run(state, frontier, max_iters=max_terms)
    # sol accumulated sum_k t^k/k! P^k; normalize by e^{-t}
    sol = np.asarray(state["sol"] + state["res"])[:layout.n]
    return {"hkpr": sol * math.exp(-t), "stats": stats}
