"""Kernel-backend registry keyed on ``(platform, kernel, monoid, dtype)``.

Every kernel call in the repo is constructed through :func:`make_kernels` /
:func:`resolve`: the engine asks for a kernel by name (``gather`` /
``scatter`` / ``spmv`` / ``fold`` / ``fused_dc``) together with its monoid
and dtype, and
the registry hands back the implementation that is actually lowerable on
the current platform — ``ref`` (pure jnp), ``pallas-interpret`` (Pallas
bodies under the interpreter, any host), or ``pallas-native`` (Mosaic,
TPU only).  Selection order:

  1. an explicit ``backend=`` argument (``Engine(..., backend=...)``),
  2. the ``REPRO_KERNEL_BACKEND`` environment variable,
  3. the platform default: ``pallas-native`` on TPU, ``ref`` elsewhere.

If the selected backend cannot lower a particular ``(kernel, monoid,
dtype)`` combination (e.g. a ``min_with_payload`` uint64 fold, or any
``pallas-native`` call on a CPU host), that *call* falls back to ``ref``
with a warning instead of failing — the rest of the engine keeps its
chosen backend.

Kernel ``fold`` (the shard_map-side blocked segmented fold,
:mod:`repro.kernels.fold_block` below ``REPRO_FOLD_MAX_SEGMENTS``
segments, the two-level :mod:`repro.kernels.fold_two_level` above it) is
the one kernel whose *platform default* is Pallas everywhere:
``pallas-native`` on TPU and ``pallas-interpret`` on other hosts, so the
distributed gather runs the paper's blocked VMEM fold at every segment
count — never ``jax.ops`` scatter-adds — unless
``REPRO_KERNEL_BACKEND=ref`` explicitly opts out.

Kernel ``fused_dc`` (the fused scatter→fold DC step,
:mod:`repro.kernels.fused_step`) is selection-special the other way:
:func:`make_kernels` constructs it only when the *selected* backend
itself lowers the ``(monoid, dtype)`` combination — no per-call ``ref``
fallback — because the engines' fallback for a missing fused kernel is
their own composed scatter→fold path, not a different backend.
``REPRO_FUSED=0`` opts the engines out of selecting it at all.
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from typing import Any, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from ..kernels import ops as kops

ENV_VAR = "REPRO_KERNEL_BACKEND"
KERNELS = ("gather", "scatter", "spmv", "fold", "fused_dc")
PALLAS_MONOIDS = ("add", "min", "max")


def _monoid_obj(monoid):
    """Accept a Monoid or a monoid name (resolved at the default dtype)."""
    if isinstance(monoid, str):
        from ..core.monoid import REGISTRY
        return REGISTRY[monoid]()
    return monoid


@runtime_checkable
class KernelBackend(Protocol):
    """Factory for layout-bound kernels sharing the engine-facing API."""

    name: str

    def supports(self, platform: str, kernel: str, monoid: str,
                 dtype) -> bool: ...

    def gather(self, layout, monoid) -> Any: ...

    def scatter(self, layout, monoid) -> Any: ...

    def spmv(self, layout, weighted=None) -> Any: ...

    def segment_fold(self, monoid, tile=None, q=None) -> Any: ...

    def fused_dc(self, layout, monoid) -> Any: ...

    def fused_stream(self, monoid, tile=None, q=None) -> Any: ...


class RefBackend:
    """Pure-jnp backend: supports every monoid the Monoid type can fold."""

    name = "ref"

    def supports(self, platform, kernel, monoid, dtype):
        if kernel == "spmv":
            return monoid == "add" and jnp.issubdtype(jnp.dtype(dtype),
                                                      jnp.floating)
        return kernel in KERNELS

    def gather(self, layout, monoid):
        return kops.RefGather(layout, _monoid_obj(monoid))

    def scatter(self, layout, monoid):
        return kops.RefScatter(layout, _monoid_obj(monoid))

    def spmv(self, layout, weighted=None):
        return kops.RefSpmv(layout, weighted=weighted)

    def segment_fold(self, monoid, tile=None, q=None):
        return kops.RefFold(_monoid_obj(monoid))

    def fused_dc(self, layout, monoid):
        return kops.RefFusedDC(layout, _monoid_obj(monoid))

    def fused_stream(self, monoid, tile=None, q=None):
        return kops.RefFusedStream(_monoid_obj(monoid))


class PallasBackend:
    """Pallas kernel bodies, interpreted (any host) or Mosaic (TPU)."""

    def __init__(self, name: str, interpret: bool):
        self.name = name
        self.interpret = interpret

    def supports(self, platform, kernel, monoid, dtype):
        if not self.interpret and platform != "tpu":
            return False                     # Mosaic lowering is TPU-only
        dt = jnp.dtype(dtype)
        if kernel == "spmv":
            return monoid == "add" and dt == jnp.float32
        if kernel not in ("gather", "scatter", "fold", "fused_dc"):
            return False
        return monoid in PALLAS_MONOIDS and dt.kind in "fiu" \
            and dt.itemsize == 4

    def gather(self, layout, monoid):
        mono = _monoid_obj(monoid)
        return kops.GatherKernel(layout, mono.name, mono.dtype,
                                 interpret=self.interpret)

    def scatter(self, layout, monoid):
        mono = _monoid_obj(monoid)
        return kops.ScatterKernel(layout, mono.name, mono.dtype,
                                  interpret=self.interpret)

    def spmv(self, layout, weighted=None):
        return kops.SpmvKernel(layout, interpret=self.interpret,
                               weighted=weighted)

    def segment_fold(self, monoid, tile=None, q=None):
        mono = _monoid_obj(monoid)
        return kops.FoldKernel(mono.name, mono.dtype,
                               interpret=self.interpret, tile=tile, q=q)

    def fused_dc(self, layout, monoid):
        mono = _monoid_obj(monoid)
        return kops.FusedDCKernel(layout, mono.name, mono.dtype,
                                  interpret=self.interpret)

    def fused_stream(self, monoid, tile=None, q=None):
        mono = _monoid_obj(monoid)
        return kops.FusedStreamKernel(mono.name, mono.dtype,
                                      interpret=self.interpret,
                                      tile=tile, q=q)


BACKENDS: dict[str, KernelBackend] = {
    "ref": RefBackend(),
    "pallas-interpret": PallasBackend("pallas-interpret", interpret=True),
    "pallas-native": PallasBackend("pallas-native", interpret=False),
}


def available_backends() -> tuple[str, ...]:
    return tuple(BACKENDS)


def default_backend_name(platform: Optional[str] = None,
                         kernel: Optional[str] = None) -> str:
    """Platform default, after the ``REPRO_KERNEL_BACKEND`` override.

    The default is per-kernel: ``fold`` (no efficient ``jax.ops``-free
    lowering exists outside Pallas) defaults to the interpreted Pallas
    kernel even on CPU hosts; everything else keeps ``ref`` off-TPU.
    """
    env = os.environ.get(ENV_VAR)
    if env:
        if env not in BACKENDS:
            raise ValueError(
                f"{ENV_VAR}={env!r} is not a known backend; "
                f"choose one of {available_backends()}")
        return env
    platform = platform or jax.default_backend()
    if platform == "tpu":
        return "pallas-native"
    return "pallas-interpret" if kernel == "fold" else "ref"


def supported(platform: str, kernel: str, monoid, dtype) -> tuple[str, ...]:
    """Registry view: backend names supporting (platform, kernel, monoid,
    dtype)."""
    mono = _monoid_obj(monoid)
    name = mono.name if not isinstance(monoid, str) else monoid
    return tuple(n for n, b in BACKENDS.items()
                 if b.supports(platform, kernel, name, dtype))


def resolve(kernel: str, monoid, dtype=None, platform: Optional[str] = None,
            choice=None) -> KernelBackend:
    """Pick the backend for one kernel call, with per-call ref fallback."""
    if kernel not in KERNELS:
        raise ValueError(f"unknown kernel {kernel!r}; expected {KERNELS}")
    mono = _monoid_obj(monoid)
    dtype = mono.dtype if dtype is None else dtype
    platform = platform or jax.default_backend()
    # a fallback is only worth a warning when the backend was *asked for*
    # (argument or env override); platform defaults degrade silently
    explicit = choice is not None or bool(os.environ.get(ENV_VAR))
    if choice is None:
        name = default_backend_name(platform, kernel)
        backend = BACKENDS[name]
    elif isinstance(choice, str):
        if choice not in BACKENDS:
            raise ValueError(f"unknown backend {choice!r}; "
                             f"choose one of {available_backends()}")
        backend = BACKENDS[choice]
    else:
        backend = choice                    # a KernelBackend instance
    if backend.supports(platform, kernel, mono.name, dtype):
        return backend
    ref = BACKENDS["ref"]
    if backend is not ref and ref.supports(platform, kernel, mono.name,
                                           dtype):
        if explicit:
            warnings.warn(
                f"backend {backend.name!r} does not lower kernel={kernel!r} "
                f"monoid={mono.name!r} dtype={jnp.dtype(dtype).name} on "
                f"platform={platform!r}; falling back to 'ref'",
                RuntimeWarning, stacklevel=2)
        return ref
    raise ValueError(
        f"no backend lowers kernel={kernel!r} monoid={mono.name!r} "
        f"dtype={jnp.dtype(dtype).name} on platform={platform!r}")


def _tag_scope(kernel, kname: str, backend_name: str):
    """Attach the ``jax.named_scope`` path the kernel's ``__call__`` enters
    (see :mod:`repro.obs.tracing`): profiler captures then attribute
    device time to ``ppm.<kernel>.<backend>`` regions.  The attribute is
    set on the kernel object itself — never a wrapper — so introspection
    like ``kset.fold.q`` keeps working."""
    try:
        kernel._obs_scope = f"ppm.{kname}.{backend_name}"
    except AttributeError:
        pass                               # e.g. a slotted/builtin callable
    return kernel


@dataclasses.dataclass
class KernelSet:
    """Layout-bound kernels for one engine, resolved per call."""

    gather: Any
    scatter: Any
    fold: Any
    spmv: Any
    names: dict                  # kernel -> backend name actually used
    fused: Any = None            # fused DC step, None -> composed path

    @property
    def any_pallas(self) -> bool:
        # the fold defaults to Pallas on every platform, so it says nothing
        # about whether the engine *chose* a Pallas backend
        return any(n.startswith("pallas") for k, n in self.names.items()
                   if k != "fold")


def make_kernels(layout, monoid, backend=None, platform=None,
                 with_spmv: bool = False) -> KernelSet:
    """Resolve and construct the gather/scatter/fold (and optionally spmv)
    kernels for a layout; each call may fall back to ``ref`` on its own."""
    mono = _monoid_obj(monoid)
    gb = resolve("gather", mono, platform=platform, choice=backend)
    sb = resolve("scatter", mono, platform=platform, choice=backend)
    fb = resolve("fold", mono, platform=platform, choice=backend)
    names = {"gather": gb.name, "scatter": sb.name, "fold": fb.name}
    spmv = None
    if with_spmv:
        vb = resolve("spmv", "add", dtype=jnp.float32, platform=platform,
                     choice=backend)
        spmv = _tag_scope(vb.spmv(layout), "spmv", vb.name)
        names["spmv"] = vb.name
    fold = fb.segment_fold(mono,
                           tile=getattr(layout, "fold_tile", None),
                           q=getattr(layout, "fold_q", None))
    # fused DC step: constructed only when the *selected* backend itself
    # lowers it — deliberately no per-call ref fallback here, because the
    # engines' fallback for a missing fused kernel is the composed
    # scatter→fold path (same backend), not a different backend
    fused = None
    platform_r = platform or jax.default_backend()
    if backend is None:
        xb = BACKENDS[default_backend_name(platform_r, "fused_dc")]
    elif isinstance(backend, str):
        xb = BACKENDS[backend]
    else:
        xb = backend
    if xb.supports(platform_r, "fused_dc", mono.name, mono.dtype):
        fused = _tag_scope(xb.fused_dc(layout, mono), "fused_dc", xb.name)
        names["fused_dc"] = xb.name
    return KernelSet(gather=_tag_scope(gb.gather(layout, mono),
                                       "gather", gb.name),
                     scatter=_tag_scope(sb.scatter(layout, mono),
                                        "scatter", sb.name),
                     fold=_tag_scope(fold, "fold", fb.name),
                     spmv=spmv, names=names, fused=fused)
