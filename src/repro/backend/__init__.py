"""Kernel-backend registry: one dispatch point for every PPM kernel call.

The paper's §6 evaluation pins GPOP's win on matching the blocking geometry
to the actual memory hierarchy: partitions sized so one partition's vertex
data lives in the private (L2) cache, bins streamed sequentially through
DRAM.  This package is the reproduction's analogue of that hardware match,
as a *backend* choice instead of a compile-time constant:

  ``ref``              pure ``jax.ops`` segment folds.  XLA:CPU fuses these
                       into the cache-friendly loops the paper's handwritten
                       OpenMP code realizes by construction — the right
                       default on CPU hosts, and the semantic oracle
                       everywhere (paper §6.1's "preprocessed once, verified
                       against a reference" discipline).
  ``pallas-interpret`` the Pallas kernel bodies executed by the interpreter.
                       Bit-level identical control flow to the TPU kernels,
                       ~100x slower than ``ref`` — a validation target, not a
                       performance point (the paper's single-thread sanity
                       runs play the same role).
  ``pallas-native``    Mosaic-compiled kernels (``interpret=False``) on TPU.
                       The paper's cache story transposed to VMEM: one
                       partition's ``q`` vertices stay VMEM-resident across
                       its bin column while edge tiles stream from HBM, so
                       DRAM→HBM and LLC→VMEM take the roles §6.2 measures.

Backends register per ``(platform, kernel, monoid, dtype)`` support;
:func:`repro.backend.registry.resolve` picks one from
``jax.default_backend()``, honours the ``REPRO_KERNEL_BACKEND`` override,
and falls back to ``ref`` per call when a lowering is unsupported.  Tile
geometry (``edge_tile``/``msg_tile``/``fold_tile`` — the §3.1
partition-sizing rule) is swept empirically by
:mod:`repro.backend.tuning` and cached on disk.

Kernel ``fold`` — the blocked segmented fold
--------------------------------------------

``resolve("fold", monoid).segment_fold(monoid, tile=None, q=None)``
returns a callable with the contract::

    acc, touched = fold(vals, valid, ids, num_segments)

    vals  [N]   message value per slot (any 4-byte add/min/max dtype)
    valid [N]   bool/int; invalid slots contribute nothing
    ids   [N]   int32 segment per slot; ids outside [0, num_segments)
                contribute nothing (engines park sentinels in the
                overflow bin num_segments - 1)
    acc     [num_segments]  monoid fold (identity where untouched)
    touched [num_segments]  bool, True iff a valid message landed there

It is the Gather phase as a *stream* kernel: no layout binding, no
``jax.ops.segment_*``, no scatter in the lowering, so it traces inside
``shard_map`` bodies and is what ``DistEngine`` folds each device's
received bin column with (and the single-device engine its compacted SC
stream).  Unlike every other kernel it defaults to Pallas on all
platforms (``pallas-native`` on TPU, ``pallas-interpret`` elsewhere);
``REPRO_KERNEL_BACKEND=ref`` opts out.

The message-tile knob — how many stream slots one grid step folds from
VMEM — resolves in order: the ``tile=`` argument (engines pass the
layout's tuned ``fold_tile``), the ``REPRO_FOLD_TILE`` environment
variable, then the static default
(:data:`repro.kernels.fold_block.DEFAULT_FOLD_TILE`).  ``autotune()``
sweeps it jointly with ``edge_tile``/``msg_tile``.

The flat blocked combine keeps the whole ``[num_segments]`` accumulator
VMEM-resident, so past ``REPRO_FOLD_MAX_SEGMENTS`` segments (default
4096 — the point where one grid step's one-hot block outgrows a TPU
core's VMEM) the kernel switches to the *two-level* blocked fold
(:mod:`repro.kernels.fold_two_level`): segments are grouped into coarse
buckets of ``q`` (the ``q=`` argument — engines pass the layout's tuned
``fold_q`` — then ``REPRO_FOLD_Q``, then
:data:`repro.kernels.fold_two_level.DEFAULT_FOLD_Q`), each bucket's
``[q]``-sized sub-accumulator folds VMEM-resident over the message
stream with a ``[fold_tile, q]`` one-hot, and per-tile bucket-range
predication skips off-bucket tiles (destination-sorted streams — the
engines' dc_bin order — do ~no redundant work).  Both regimes are
Pallas lowerings with identical semantics; there is no silent handoff
to ``ref`` at any segment count — ``REPRO_KERNEL_BACKEND=ref`` is the
only way to get the ``jax.ops`` fold.  ``autotune()`` sweeps ``fold_q``
jointly with ``fold_tile`` via the over-cap ``fold2`` timing row.

The DC step: composed vs fused dataflow
---------------------------------------

The engines' DC stream has two lowerings.  The *composed* path is the
paper's literal pipeline: the ``scatter`` kernel writes the dense
``[NM]`` bin buffer (values only, the pre-written dc_bin), a slot
gather re-reads it into an ``[NE]`` per-edge value stream, and the
gather-side fold collapses that into the per-partition accumulators —
two HBM round-trips per superstep for data that is only ever consumed
once.  The *fused* path is registry kernel ``fused_dc``
(:mod:`repro.kernels.fused_step`): one Pallas launch whose grid walks
``(segment buckets × edge tiles)``, gathers each edge's source value
straight from the VMEM-resident message table, applies the optional
edge function, and folds into the two-level ``[fold_q]``
sub-accumulators — neither intermediate ever materializes, and the
input-block pipeline double-buffers edge-tile fetches against the
combine.  Its stream contract mirrors the fold's::

    acc, touched = fused(table, table_valid, idx, edge_valid, dst,
                         num_segments[, w=, apply_weight=])

with an edge contributing iff ``table_valid[idx] & edge_valid`` — the
same elementwise condition the composed path computes via the scatter
flags, so the two lowerings are bit-exact against each other (enforced
by ``tests/test_fused_property.py`` through the shared differential
harness).

Selection rule: the engines take the fused kernel from
:func:`make_kernels` / ``fused_dc`` resolution when (a) ``REPRO_FUSED``
is not ``0`` and (b) the *selected* backend itself lowers the
``(monoid, dtype)`` combination — {add,min,max} × 4-byte f/i/u for the
Pallas backends, everything for ``ref``.  Unlike the other kernels
there is deliberately NO per-call ``ref`` fallback: a missing fused
lowering silently keeps the engine on its composed path (same backend),
which also remains the lowering for the SC and hybrid-SC streams, for
``pallas-native`` requests off-TPU, and for monoids outside the Pallas
set.  ``autotune()`` observes the fused grid's ``edge_tile × fold_q``
cross-product through the ``fused`` timing row, and the winners ride
the same cached :class:`~repro.backend.tuning.TileGeometry` the
layouts are built from.

Telemetry
---------

:func:`make_kernels` tags every kernel object it hands out with an
``_obs_scope`` of the form ``ppm.<kernel>.<backend>`` (e.g.
``ppm.fold.pallas-interpret``) — the tag is set on the object itself,
never a wrapper, so geometry introspection like ``kset.fold.q`` keeps
working.  Each kernel ``__call__`` enters that scope via
``repro.obs.tracing.kernel_scope`` (a ``jax.named_scope``: trace-time
metadata only, zero retraces and zero runtime cost), so ``jax.profiler``
captures — ``repro.obs.trace(path)`` starts one — attribute device time
to *which kernel under which backend*, the attribution the registry's
per-call ``ref`` fallback would otherwise blur.  ``REPRO_OBS=0``
degrades the scope to a ``nullcontext``.  See :mod:`repro.obs`.
"""
from __future__ import annotations

from .registry import (BACKENDS, KernelBackend, available_backends,
                       default_backend_name, make_kernels, resolve,
                       supported)
from .tuning import (DEFAULT_GEOMETRY, TileGeometry, autotune,
                     resolve_geometry, tuned_layout)

__all__ = [
    "BACKENDS", "KernelBackend", "available_backends",
    "default_backend_name", "make_kernels", "resolve", "supported",
    "DEFAULT_GEOMETRY", "TileGeometry", "autotune", "resolve_geometry",
    "tuned_layout",
]
