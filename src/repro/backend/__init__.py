"""Kernel-backend registry: one dispatch point for every PPM kernel call.

The paper's §6 evaluation pins GPOP's win on matching the blocking geometry
to the actual memory hierarchy: partitions sized so one partition's vertex
data lives in the private (L2) cache, bins streamed sequentially through
DRAM.  This package is the reproduction's analogue of that hardware match,
as a *backend* choice instead of a compile-time constant:

  ``ref``              pure ``jax.ops`` segment folds.  XLA:CPU fuses these
                       into the cache-friendly loops the paper's handwritten
                       OpenMP code realizes by construction — the right
                       default on CPU hosts, and the semantic oracle
                       everywhere (paper §6.1's "preprocessed once, verified
                       against a reference" discipline).
  ``pallas-interpret`` the Pallas kernel bodies executed by the interpreter.
                       Bit-level identical control flow to the TPU kernels,
                       ~100x slower than ``ref`` — a validation target, not a
                       performance point (the paper's single-thread sanity
                       runs play the same role).
  ``pallas-native``    Mosaic-compiled kernels (``interpret=False``) on TPU.
                       The paper's cache story transposed to VMEM: one
                       partition's ``q`` vertices stay VMEM-resident across
                       its bin column while edge tiles stream from HBM, so
                       DRAM→HBM and LLC→VMEM take the roles §6.2 measures.

Backends register per ``(platform, kernel, monoid, dtype)`` support;
:func:`repro.backend.registry.resolve` picks one from
``jax.default_backend()``, honours the ``REPRO_KERNEL_BACKEND`` override,
and falls back to ``ref`` per call when a lowering is unsupported.  Tile
geometry (``edge_tile``/``msg_tile``/``fold_tile`` — the §3.1
partition-sizing rule) is swept empirically by
:mod:`repro.backend.tuning` and cached on disk.

Kernel ``fold`` — the blocked segmented fold
--------------------------------------------

``resolve("fold", monoid).segment_fold(monoid, tile=None, q=None)``
returns a callable with the contract::

    acc, touched = fold(vals, valid, ids, num_segments)

    vals  [N]   message value per slot (any 4-byte add/min/max dtype)
    valid [N]   bool/int; invalid slots contribute nothing
    ids   [N]   int32 segment per slot; ids outside [0, num_segments)
                contribute nothing (engines park sentinels in the
                overflow bin num_segments - 1)
    acc     [num_segments]  monoid fold (identity where untouched)
    touched [num_segments]  bool, True iff a valid message landed there

It is the Gather phase as a *stream* kernel: no layout binding, no
``jax.ops.segment_*``, no scatter in the lowering, so it traces inside
``shard_map`` bodies and is what ``DistEngine`` folds each device's
received bin column with (and the single-device engine its compacted SC
stream).  Unlike every other kernel it defaults to Pallas on all
platforms (``pallas-native`` on TPU, ``pallas-interpret`` elsewhere);
``REPRO_KERNEL_BACKEND=ref`` opts out.

The message-tile knob — how many stream slots one grid step folds from
VMEM — resolves in order: the ``tile=`` argument (engines pass the
layout's tuned ``fold_tile``), the ``REPRO_FOLD_TILE`` environment
variable, then the static default
(:data:`repro.kernels.fold_block.DEFAULT_FOLD_TILE`).  ``autotune()``
sweeps it jointly with ``edge_tile``/``msg_tile``.

The flat blocked combine keeps the whole ``[num_segments]`` accumulator
VMEM-resident, so past ``REPRO_FOLD_MAX_SEGMENTS`` segments (default
4096 — the point where one grid step's one-hot block outgrows a TPU
core's VMEM) the kernel switches to the *two-level* blocked fold
(:mod:`repro.kernels.fold_two_level`): segments are grouped into coarse
buckets of ``q`` (the ``q=`` argument — engines pass the layout's tuned
``fold_q`` — then ``REPRO_FOLD_Q``, then
:data:`repro.kernels.fold_two_level.DEFAULT_FOLD_Q`), each bucket's
``[q]``-sized sub-accumulator folds VMEM-resident over the message
stream with a ``[fold_tile, q]`` one-hot, and per-tile bucket-range
predication skips off-bucket tiles (destination-sorted streams — the
engines' dc_bin order — do ~no redundant work).  Both regimes are
Pallas lowerings with identical semantics; there is no silent handoff
to ``ref`` at any segment count — ``REPRO_KERNEL_BACKEND=ref`` is the
only way to get the ``jax.ops`` fold.  ``autotune()`` sweeps ``fold_q``
jointly with ``fold_tile`` via the over-cap ``fold2`` timing row.

Telemetry
---------

:func:`make_kernels` tags every kernel object it hands out with an
``_obs_scope`` of the form ``ppm.<kernel>.<backend>`` (e.g.
``ppm.fold.pallas-interpret``) — the tag is set on the object itself,
never a wrapper, so geometry introspection like ``kset.fold.q`` keeps
working.  Each kernel ``__call__`` enters that scope via
``repro.obs.tracing.kernel_scope`` (a ``jax.named_scope``: trace-time
metadata only, zero retraces and zero runtime cost), so ``jax.profiler``
captures — ``repro.obs.trace(path)`` starts one — attribute device time
to *which kernel under which backend*, the attribution the registry's
per-call ``ref`` fallback would otherwise blur.  ``REPRO_OBS=0``
degrades the scope to a ``nullcontext``.  See :mod:`repro.obs`.
"""
from __future__ import annotations

from .registry import (BACKENDS, KernelBackend, available_backends,
                       default_backend_name, make_kernels, resolve,
                       supported)
from .tuning import (DEFAULT_GEOMETRY, TileGeometry, autotune,
                     resolve_geometry, tuned_layout)

__all__ = [
    "BACKENDS", "KernelBackend", "available_backends",
    "default_backend_name", "make_kernels", "resolve", "supported",
    "DEFAULT_GEOMETRY", "TileGeometry", "autotune", "resolve_geometry",
    "tuned_layout",
]
