"""Kernel-backend registry: one dispatch point for every PPM kernel call.

The paper's §6 evaluation pins GPOP's win on matching the blocking geometry
to the actual memory hierarchy: partitions sized so one partition's vertex
data lives in the private (L2) cache, bins streamed sequentially through
DRAM.  This package is the reproduction's analogue of that hardware match,
as a *backend* choice instead of a compile-time constant:

  ``ref``              pure ``jax.ops`` segment folds.  XLA:CPU fuses these
                       into the cache-friendly loops the paper's handwritten
                       OpenMP code realizes by construction — the right
                       default on CPU hosts, and the semantic oracle
                       everywhere (paper §6.1's "preprocessed once, verified
                       against a reference" discipline).
  ``pallas-interpret`` the Pallas kernel bodies executed by the interpreter.
                       Bit-level identical control flow to the TPU kernels,
                       ~100x slower than ``ref`` — a validation target, not a
                       performance point (the paper's single-thread sanity
                       runs play the same role).
  ``pallas-native``    Mosaic-compiled kernels (``interpret=False``) on TPU.
                       The paper's cache story transposed to VMEM: one
                       partition's ``q`` vertices stay VMEM-resident across
                       its bin column while edge tiles stream from HBM, so
                       DRAM→HBM and LLC→VMEM take the roles §6.2 measures.

Backends register per ``(platform, kernel, monoid, dtype)`` support;
:func:`repro.backend.registry.resolve` picks one from
``jax.default_backend()``, honours the ``REPRO_KERNEL_BACKEND`` override,
and falls back to ``ref`` per call when a lowering is unsupported.  Tile
geometry (``edge_tile``/``msg_tile`` — the §3.1 partition-sizing rule) is
swept empirically by :mod:`repro.backend.tuning` and cached on disk.
"""
from __future__ import annotations

from .registry import (BACKENDS, KernelBackend, available_backends,
                       default_backend_name, make_kernels, resolve,
                       supported)
from .tuning import (DEFAULT_GEOMETRY, TileGeometry, autotune,
                     resolve_geometry, tuned_layout)

__all__ = [
    "BACKENDS", "KernelBackend", "available_backends",
    "default_backend_name", "make_kernels", "resolve", "supported",
    "DEFAULT_GEOMETRY", "TileGeometry", "autotune", "resolve_geometry",
    "tuned_layout",
]
