"""Tile-geometry autotuner: sweep ``edge_tile``/``msg_tile``/``fold_tile``
(and the two-level fold's bucket width ``fold_q``).

The paper's §3.1 sizing rule ("one partition's vertex data fits the private
cache") fixes ``q``; what it leaves open — and what §6.4 shows matters — is
the streaming granularity of the bins.  Here that granularity is the Pallas
block geometry ``(edge_tile, msg_tile, fold_tile)``, and instead of a
hardcoded constant the tuner times real compiled kernel calls per
candidate, keeps the fastest, and caches the winner on disk
(``results/tuning/*.json``).  :func:`repro.graph.layout.build_layout`
consults the same cache when its tile arguments are left unset, so a
one-off ``autotune()`` run feeds every subsequent layout build on this
host.

``fold_tile`` — the message-block size of the blocked segmented fold
(:mod:`repro.kernels.fold_block`) — is swept *jointly* with the other two:
Eq. 1's cost model prices the gather traffic as a function of both the
bin-stream granularity and the per-partition accumulator residency, so
the best fold tile shifts with ``edge_tile`` (a bigger edge tile raises
the message density per bin column and favours a bigger fold block).

``fold_q`` — the bucket width of the two-level fold
(:mod:`repro.kernels.fold_two_level`, the over-cap regime) — is swept
jointly with ``fold_tile``: the two-level one-hot block is
``[fold_tile, fold_q]``, so the same Eq. 1 trade (block size vs number of
grid revisits) couples the two knobs.  The ``fold2`` kernel row times the
two-level path on an over-cap synthetic stream so the sweep can actually
observe ``fold_q`` (below the cap the registry fold never runs it), and
the ``fused`` row times the fused scatter→fold DC step
(:mod:`repro.kernels.fused_step`) on the layout's real edge stream —
its grid is ``(segments/fold_q, edges/edge_tile)``, so that row sweeps
the ``edge_tile × fold_q`` cross-product directly; winners land in the
same cached geometry :func:`repro.graph.layout.build_layout` consults.

Cache entries are keyed by (platform, backend, log2-bucketed graph size,
partition count): geometry is a property of the memory hierarchy and the
scale family, not of one concrete edge set.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import registry


@dataclasses.dataclass(frozen=True)
class TileGeometry:
    edge_tile: int = 256
    msg_tile: int = 128
    fold_tile: int = 256
    fold_q: int = 256         # two-level fold bucket width (over-cap regime)


DEFAULT_GEOMETRY = TileGeometry()

# Candidate sweeps per platform.  CPU candidates go small (interpret-mode
# grids and XLA:CPU loops both favour short tiles); TPU candidates stay
# lane-aligned multiples of 128 going up to the VMEM budget.  fold_tile
# moves with edge_tile (denser bin columns favour bigger fold blocks) and
# each edge_tile point carries two fold_tile points so the joint optimum
# is observable rather than assumed; fold_q moves with fold_tile (the
# two-level one-hot block is [fold_tile, fold_q], so the VMEM budget
# couples them) with two fold_q points per fold_tile point.
CANDIDATES = {
    "cpu": (TileGeometry(64, 32, 64, 64), TileGeometry(128, 64, 128, 128),
            TileGeometry(128, 64, 256, 128),
            TileGeometry(256, 128, 256, 256),
            TileGeometry(256, 128, 512, 256),
            TileGeometry(512, 256, 512, 512)),
    "tpu": (TileGeometry(256, 128, 256, 128),
            TileGeometry(512, 256, 512, 256),
            TileGeometry(512, 256, 1024, 256),
            TileGeometry(1024, 512, 1024, 512),
            TileGeometry(1024, 512, 2048, 512),
            TileGeometry(2048, 1024, 2048, 1024)),
}

ENV_DIR = "REPRO_TUNING_DIR"
_REPO_ROOT = Path(__file__).resolve().parents[3]


def candidates(platform: Optional[str] = None) -> tuple[TileGeometry, ...]:
    platform = platform or jax.default_backend()
    return CANDIDATES.get(platform, CANDIDATES["cpu"])


def cache_dir_path(cache_dir=None) -> Path:
    if cache_dir is not None:
        return Path(cache_dir)
    env = os.environ.get(ENV_DIR)
    return Path(env) if env else _REPO_ROOT / "results" / "tuning"


def _cache_key(n: int, m: int, k: int, weighted: bool, platform: str,
               backend: str) -> str:
    # log2 buckets: one sweep covers the whole scale family
    return (f"{platform}-{backend}-n{int(n).bit_length()}"
            f"-m{int(m).bit_length()}-k{k}-{'w' if weighted else 'u'}")


def load_cached(n, m, k, weighted, platform, backend,
                cache_dir=None) -> Optional[TileGeometry]:
    path = cache_dir_path(cache_dir) / (
        _cache_key(n, m, k, weighted, platform, backend) + ".json")
    if not path.exists():
        return None
    try:
        rec = json.loads(path.read_text())
        # a cache entry predating a knob was swept without it: treat it as
        # a miss so autotune() re-sweeps instead of pinning the new knob
        # to its untuned default forever
        return TileGeometry(int(rec["edge_tile"]), int(rec["msg_tile"]),
                            int(rec["fold_tile"]), int(rec["fold_q"]))
    except (ValueError, KeyError):
        return None


def resolve_geometry(n: int, m: int, k: int, weighted: bool = False,
                     platform: Optional[str] = None, backend=None,
                     cache_dir=None) -> TileGeometry:
    """Tuned geometry if a cached sweep covers this graph family, else the
    static default.  Never runs a sweep itself (layout builds stay cheap)."""
    platform = platform or jax.default_backend()
    bname = backend or registry.default_backend_name(platform)
    if not isinstance(bname, str):
        bname = bname.name
    return (load_cached(n, m, k, weighted, platform, bname, cache_dir)
            or DEFAULT_GEOMETRY)


def _timed(fn, reps: int) -> float:
    jax.block_until_ready(fn())            # warmup + compile
    best = np.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def time_layout(layout, backend_name: str, platform: str,
                kernels=("gather", "scatter", "spmv", "fold", "fold2",
                         "fused"),
                reps: int = 3,
                monoid: str = "add", fold_backend=None) -> dict:
    """Time one compiled call of each kernel on a built layout.

    ``fold_backend`` overrides the backend for the fold *and fused* rows
    only: the autotuner passes the *per-kernel* platform default there,
    because the fold's default backend (Pallas everywhere) differs from
    the other kernels' and ``RefFold``/``RefFusedDC`` ignore the tile
    knobs — sweeping them through ref would select the winner by timing
    jitter.  The ``fused`` row times the fused scatter→fold DC step on
    the layout's real edge stream, so the sweep observes the
    ``edge_tile × fold_q`` cross-product the fused kernel's grid is
    built from."""
    rng = np.random.default_rng(0)
    out = {}
    dtype = jnp.float32
    # jit the layout-bound callables so the ref backend is timed as one
    # compiled program, exactly as the engines run it
    if "gather" in kernels:
        b = registry.resolve("gather", monoid, dtype=dtype,
                             platform=platform, choice=backend_name)
        gk = jax.jit(b.gather(layout, monoid).__call__)
        ev = jnp.asarray(
            rng.integers(0, 64, layout.num_edges).astype(np.float32))
        valid = jnp.asarray(layout.edge_valid)
        pa = jnp.ones((layout.k,), jnp.int32)
        out["gather"] = _timed(lambda: gk(ev, valid, pa), reps)
    if "scatter" in kernels:
        b = registry.resolve("scatter", monoid, dtype=dtype,
                             platform=platform, choice=backend_name)
        sk = jax.jit(b.scatter(layout, monoid).__call__)
        x = jnp.asarray(rng.integers(0, 64, layout.n_pad).astype(np.float32))
        act = jnp.ones((layout.n_pad,), jnp.int32)
        out["scatter"] = _timed(lambda: sk(x, act), reps)
    if "spmv" in kernels:
        b = registry.resolve("spmv", "add", dtype=dtype, platform=platform,
                             choice=backend_name)
        vk = jax.jit(b.spmv(layout).__call__)
        x = jnp.asarray(rng.integers(0, 64, layout.n_pad).astype(np.float32))
        out["spmv"] = _timed(lambda: vk(x), reps)
    def _time_fold(key: str, ns: int, ids_np):
        b = registry.resolve("fold", monoid, dtype=dtype, platform=platform,
                             choice=fold_backend or backend_name)
        fold = b.segment_fold(monoid, tile=getattr(layout, "fold_tile",
                                                   None),
                              q=getattr(layout, "fold_q", None))
        fv = jnp.asarray(
            rng.integers(0, 64, layout.num_edges).astype(np.float32))
        fvalid = jnp.asarray(layout.edge_valid)
        fids = jnp.where(fvalid, jnp.asarray(ids_np), ns - 1)
        fk = jax.jit(lambda v, va, i: fold(v, va, i, ns))
        out[key] = _timed(lambda: fk(fv, fvalid, fids), reps)

    if "fold" in kernels:
        # the layout's gather-order edge stream doubles as a realistic
        # message stream: ids = edge destinations, overflow bin = n_pad
        _time_fold("fold", layout.n_pad + 1, layout.edge_dst)
    if "fold2" in kernels:
        # the over-cap regime: a synthetic stream with num_segments past
        # REPRO_FOLD_MAX_SEGMENTS, so the two-level fold (and its fold_q
        # knob) is what actually gets timed; sorted ids model the engines'
        # destination-major dc_bin order — the regime where the two-level
        # bucket-range skip earns its keep
        from ..kernels.fold_block import max_fold_segments
        ns2 = max_fold_segments() + max_fold_segments() // 2 + 1
        _time_fold("fold2", ns2,
                   np.sort(rng.integers(0, ns2 - 1, layout.num_edges))
                   .astype(np.int32))
    if "fused" in kernels:
        # the fused DC step over the layout's real edge stream: its grid
        # is (segments/fold_q, edges/edge_tile), so this row is the one
        # place the sweep observes the edge_tile × fold_q cross-product
        b = registry.resolve("fused_dc", monoid, dtype=dtype,
                             platform=platform,
                             choice=fold_backend or backend_name)
        fk = jax.jit(b.fused_dc(layout, monoid).__call__)
        table = jnp.asarray(
            rng.integers(0, 64, layout.n_pad + 1).astype(np.float32))
        tvalid = jnp.ones((layout.n_pad + 1,), jnp.bool_) \
            .at[-1].set(False)
        out["fused"] = _timed(lambda: fk(table, tvalid), reps)
    return out


def autotune(g, k: Optional[int] = None, backend=None,
             platform: Optional[str] = None,
             kernels=("gather", "scatter", "spmv", "fold", "fold2",
                      "fused"),
             reps: int = 3,
             cache_dir=None, force: bool = False) -> TileGeometry:
    """Sweep candidate tile geometries for graph ``g``; cache the winner.

    Returns the fastest :class:`TileGeometry` by summed kernel time.  The
    winner is written to ``<cache_dir>/<key>.json`` so later
    ``build_layout(..., edge_tile=None)`` calls on the same graph family
    pick it up without re-sweeping.
    """
    from ..graph.layout import build_layout, resolve_k
    platform = platform or jax.default_backend()
    bname = backend or registry.default_backend_name(platform)
    if not isinstance(bname, str):
        bname = bname.name
    kk = resolve_k(g.n, k)
    if not force:
        hit = load_cached(g.n, g.m, kk, g.weighted, platform, bname,
                          cache_dir)
        if hit is not None:
            return hit
    # sweep the fold through the backend engines really resolve for it
    # (Pallas by default) unless the caller pinned one explicitly
    fold_bname = (bname if backend is not None
                  else registry.default_backend_name(platform, "fold"))
    sweeps = []
    for geom in candidates(platform):
        L = build_layout(g, k=k, edge_tile=geom.edge_tile,
                         msg_tile=geom.msg_tile,
                         fold_tile=geom.fold_tile,
                         fold_q=geom.fold_q)
        times = time_layout(L, bname, platform, kernels=kernels, reps=reps,
                            fold_backend=fold_bname)
        sweeps.append({"edge_tile": geom.edge_tile,
                       "msg_tile": geom.msg_tile,
                       "fold_tile": geom.fold_tile,
                       "fold_q": geom.fold_q,
                       "wall_s": sum(times.values()), "kernels": times})
    best = min(sweeps, key=lambda s: s["wall_s"])
    rec = {
        "edge_tile": best["edge_tile"], "msg_tile": best["msg_tile"],
        "fold_tile": best["fold_tile"], "fold_q": best["fold_q"],
        "platform": platform, "backend": bname,
        "graph": {"n": int(g.n), "m": int(g.m), "k": int(kk),
                  "weighted": bool(g.weighted)},
        "sweep": sweeps,
        "created": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }
    cdir = cache_dir_path(cache_dir)
    cdir.mkdir(parents=True, exist_ok=True)
    key = _cache_key(g.n, g.m, kk, g.weighted, platform, bname)
    (cdir / f"{key}.json").write_text(json.dumps(rec, indent=2))
    return TileGeometry(best["edge_tile"], best["msg_tile"],
                        best["fold_tile"], best["fold_q"])


def tuned_layout(g, k: Optional[int] = None, backend=None,
                 platform: Optional[str] = None, cache_dir=None,
                 force: bool = False, **build_kw):
    """Autotune (or read the cached sweep) and build the layout with the
    winning geometry."""
    from ..graph.layout import build_layout
    geom = autotune(g, k=k, backend=backend, platform=platform,
                    cache_dir=cache_dir, force=force)
    return build_layout(g, k=k, edge_tile=geom.edge_tile,
                        msg_tile=geom.msg_tile, fold_tile=geom.fold_tile,
                        fold_q=geom.fold_q, **build_kw)
