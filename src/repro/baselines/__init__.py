from . import vc
