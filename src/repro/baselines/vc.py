"""Vertex-centric and edge-centric baselines (the paper's comparison
targets, reimplemented in JAX):

  vc_push   - Ligra-style frontier-driven push (work ~ E_a, random writes;
              the atomic-update pattern becomes segment folds here)
  vc_pull   - Ligra-style pull direction (work ~ E every iteration)
  ec_stream - X-Stream-style unordered edge streaming (work ~ E)
  spmv      - GraphMat-style masked sparse-matrix-vector product (work ~ E
              + O(V) frontier handling)

Each provides bfs/pagerank/sssp/cc so benchmarks/fig4 can compare against
GPOP on identical inputs.  None of them partition: the memory-access pattern
is the whole point of the contrast.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.csr import Graph


def _prep(g: Graph):
    src = np.repeat(np.arange(g.n, dtype=np.int32),
                    g.out_degrees().astype(np.int64))
    return {
        "src": jnp.asarray(src),
        "dst": jnp.asarray(g.indices),
        "w": jnp.asarray(g.weights) if g.weights is not None else None,
        "n": g.n, "m": g.m,
        "deg": jnp.asarray(g.out_degrees().astype(np.float32)),
    }


# ---------------------------------------------------------------------------
# BFS
# ---------------------------------------------------------------------------

def _bfs_engine(g: Graph, source: int, order: str):
    """order: 'push' (frontier mask on src), 'pull'/'ec' (all edges)."""
    E = _prep(g)
    n = E["n"]

    @jax.jit
    def step(level, active, it):
        if order == "push":
            live = active[E["src"]]
        else:
            live = level[E["src"]] >= 0
        cand = jnp.where(live, E["src"], n)
        acc = jax.ops.segment_min(
            jnp.where(live, level[E["src"]], 2**30),
            E["dst"], num_segments=n + 1)[:n]
        hit = (acc < 2**30) & (level < 0)
        level = jnp.where(hit, it + 1, level)
        return level, hit

    level = jnp.full((n,), -1, jnp.int32).at[source].set(0)
    active = jnp.zeros((n,), bool).at[source].set(True)
    for it in range(n):
        level, active = step(level, active, jnp.int32(it))
        if int(active.sum()) == 0:
            break
    return np.asarray(level)


def bfs_push(g, source):
    return _bfs_engine(g, source, "push")


def bfs_pull(g, source):
    return _bfs_engine(g, source, "pull")


def bfs_ec(g, source):
    return _bfs_engine(g, source, "ec")


# ---------------------------------------------------------------------------
# PageRank (SpMV-style: GraphMat)
# ---------------------------------------------------------------------------

def pagerank_spmv(g: Graph, iters: int = 10, damping: float = 0.85):
    E = _prep(g)
    n = E["n"]

    @jax.jit
    def run(pr):
        def body(_, pr):
            contrib = jnp.where(E["deg"] > 0, pr / E["deg"], 0.0)
            acc = jax.ops.segment_sum(contrib[E["src"]], E["dst"],
                                      num_segments=n)
            return (1 - damping) / n + damping * acc
        return jax.lax.fori_loop(0, iters, body, pr)

    pr = run(jnp.full((n,), 1.0 / n, jnp.float32))
    return np.asarray(pr)


# ---------------------------------------------------------------------------
# SSSP (Bellman-Ford, push and full-edge variants)
# ---------------------------------------------------------------------------

def sssp_push(g: Graph, source: int):
    E = _prep(g)
    n = E["n"]

    @jax.jit
    def step(dist, active):
        live = active[E["src"]]
        relax = jnp.where(live, dist[E["src"]] + E["w"], jnp.inf)
        acc = jax.ops.segment_min(relax, E["dst"], num_segments=n + 1)[:n]
        better = acc < dist
        return jnp.where(better, acc, dist), better

    dist = jnp.full((n,), jnp.inf, jnp.float32).at[source].set(0.0)
    active = jnp.zeros((n,), bool).at[source].set(True)
    for _ in range(n):
        dist, active = step(dist, active)
        if int(active.sum()) == 0:
            break
    return np.asarray(dist)


# ---------------------------------------------------------------------------
# Connected components (label propagation over all edges: EC style)
# ---------------------------------------------------------------------------

def cc_ec(g: Graph):
    E = _prep(g)
    n = E["n"]

    @jax.jit
    def step(label):
        acc = jax.ops.segment_min(label[E["src"]], E["dst"],
                                  num_segments=n + 1)[:n]
        new = jnp.minimum(label, acc)
        return new, jnp.any(new != label)

    label = jnp.arange(n, dtype=jnp.uint32)
    for _ in range(n):
        label, changed = step(label)
        if not bool(changed):
            break
    return np.asarray(label)


def timed(fn, *args, repeat: int = 1, **kw):
    fn(*args, **kw)                      # warmup/compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    return (time.perf_counter() - t0) / repeat, out
