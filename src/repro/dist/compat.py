"""JAX version shim: one sharding API surface across 0.4.x-0.5.x.

The repo targets the modern spelling (``jax.sharding.AxisType``,
``AbstractMesh(axis_sizes, axis_names)``, ``jax.make_mesh(...,
axis_types=...)``, ``jax.shard_map``).  On the pinned 0.4.37 none of those
exist in that form, so this module provides equivalents and — on import —
installs them into ``jax`` / ``jax.sharding`` so that code written against
the new API (including the test suite) imports and runs unchanged.

Import this module (or anything under ``repro.dist``) before touching
``jax.sharding.AxisType`` etc.; ``tests/conftest.py`` does so for the test
suite, and launcher entrypoints go through :func:`make_mesh` directly.
"""
from __future__ import annotations

import enum
import inspect

import jax
import jax.sharding as _jsharding
from jax.sharding import Mesh, NamedSharding, PartitionSpec

__all__ = ["AxisType", "AbstractMesh", "Mesh", "NamedSharding",
           "PartitionSpec", "make_mesh", "shard_map", "cost_analysis",
           "install"]


def cost_analysis(compiled):
    """``compiled.cost_analysis()`` as one flat dict on every version
    (0.4.x returns a list with one per-device dict, 0.5.x+ a dict)."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


# ----------------------------------------------------------------------
# AxisType (jax >= 0.5.x)
# ----------------------------------------------------------------------

try:
    from jax.sharding import AxisType          # noqa: F401  (0.5.x+)
except ImportError:
    class AxisType(enum.Enum):
        """Stand-in for ``jax.sharding.AxisType``.

        0.4.x meshes behave like all-``Auto`` axes, so mesh constructors
        below simply drop the argument there; the enum exists so callers
        can spell ``axis_types=(AxisType.Auto,) * n`` portably.
        """
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


# ----------------------------------------------------------------------
# make_mesh with axis_types on every version
# ----------------------------------------------------------------------

_ORIG_MAKE_MESH = getattr(jax.make_mesh, "__wrapped_orig__", jax.make_mesh)
_MAKE_MESH_PARAMS = inspect.signature(_ORIG_MAKE_MESH).parameters


def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
    """``jax.make_mesh`` accepting ``axis_types`` on any JAX version."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if axis_types is not None and "axis_types" in _MAKE_MESH_PARAMS:
        kwargs["axis_types"] = axis_types
    return _ORIG_MAKE_MESH(tuple(axis_shapes), tuple(axis_names), **kwargs)


make_mesh.__wrapped_orig__ = _ORIG_MAKE_MESH


# ----------------------------------------------------------------------
# AbstractMesh: new-style (axis_sizes, axis_names) constructor everywhere
# ----------------------------------------------------------------------

_RealAbstractMesh = getattr(_jsharding.AbstractMesh, "__wrapped_orig__",
                            _jsharding.AbstractMesh)
_ABS_OLD_STYLE = "shape_tuple" in inspect.signature(
    _RealAbstractMesh.__init__).parameters


def AbstractMesh(axis_shapes, axis_names=None, *, axis_types=None):
    """Device-free mesh geometry, new-style signature on any version.

    Accepts either ``AbstractMesh((2, 2), ("data", "model"))`` (0.5.x
    spelling) or the legacy ``AbstractMesh((("data", 2), ("model", 2)))``.
    ``axis_types`` is forwarded where supported and dropped on 0.4.x
    (whose meshes are implicitly all-Auto).
    """
    if axis_names is None:                     # legacy pair-tuple call
        pairs = tuple(axis_shapes)
        sizes = tuple(s for _, s in pairs)
        names = tuple(n for n, _ in pairs)
    else:
        sizes = tuple(axis_shapes)
        names = tuple(axis_names)
        pairs = tuple(zip(names, sizes))
    if _ABS_OLD_STYLE:
        return _RealAbstractMesh(pairs)
    if axis_types is not None:
        return _RealAbstractMesh(sizes, names, axis_types=axis_types)
    return _RealAbstractMesh(sizes, names)


AbstractMesh.__wrapped_orig__ = _RealAbstractMesh


# ----------------------------------------------------------------------
# shard_map: jax.shard_map signature (check_vma) on every version
# ----------------------------------------------------------------------

if hasattr(jax, "shard_map") and not hasattr(jax.shard_map,
                                             "__wrapped_orig__"):
    _ORIG_SHARD_MAP = jax.shard_map

    def shard_map(f, mesh, in_specs, out_specs, **kwargs):
        return _ORIG_SHARD_MAP(f, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, **kwargs)
else:
    from jax.experimental.shard_map import shard_map as _experimental_smap

    def shard_map(f, mesh, in_specs, out_specs, *, check_vma=None,
                  check_rep=None, auto=None):
        # 0.4.x spells the validity check ``check_rep``; its checker
        # predates several collectives used here (all_to_all bodies), so
        # default it OFF unless explicitly requested — it is a
        # validation/optimization flag, never a semantics change.
        # Other kwargs are NOT silently dropped: a semantics-affecting
        # option the old API cannot honor must fail loudly.
        rep = check_rep if check_rep is not None else bool(check_vma)
        kwargs = {} if auto is None else {"auto": auto}
        return _experimental_smap(f, mesh=mesh, in_specs=in_specs,
                                  out_specs=out_specs, check_rep=rep,
                                  **kwargs)

shard_map.__wrapped_orig__ = getattr(jax, "shard_map", None)


# ----------------------------------------------------------------------
# install: make the modern spellings importable from jax itself
# ----------------------------------------------------------------------

def install():
    """Idempotently patch ``jax`` / ``jax.sharding`` with the shims so code
    written against the 0.5.x API (``from jax.sharding import AxisType``,
    ``jax.make_mesh(..., axis_types=...)``) runs on the pinned 0.4.37."""
    if not hasattr(_jsharding, "AxisType"):
        _jsharding.AxisType = AxisType
    if _ABS_OLD_STYLE and _jsharding.AbstractMesh is not AbstractMesh:
        _jsharding.AbstractMesh = AbstractMesh
    if "axis_types" not in _MAKE_MESH_PARAMS and jax.make_mesh is not make_mesh:
        jax.make_mesh = make_mesh
    if not hasattr(jax, "shard_map"):
        jax.shard_map = shard_map


install()
