"""Logical-axis -> mesh-axis sharding rules for every multi-device path.

Model params carry twin "logical axes" pytrees (``init_lm`` returns
``(params, axes)``; leaves are tuples like ``("embed", "heads")``).  This
module owns the single mapping from those names onto mesh axes:

  * ``default_rules(mesh, cfg)``   the rule table (FSDP data axes for
    ``embed``/``batch``, tensor-parallel ``model`` for heads/kv/ff/vocab),
    with per-config overrides via ``cfg.sharding_overrides``;
  * ``spec_for(axes, shape, ...)`` rules -> ``PartitionSpec`` with two
    guards: a dim that does not divide its mesh-axis extent is replicated,
    and each mesh axis is consumed at most once per tensor;
  * ``param_shardings``            the whole-params-tree application;
  * ``constrain`` / ``set_activation_mesh``  activation sharding hints
    inside jitted model code (no-ops until a mesh is activated);
  * ``batch_spec`` / ``graph_spec``  the two non-param layouts: LM batches
    over the data axes, PPM graph arrays over ALL axes flattened.
"""
from __future__ import annotations

import jax
import numpy as np

from .compat import NamedSharding, PartitionSpec as P

# Activation-constraint mesh, a one-element box so model code can read the
# *current* mesh at trace time (``_ACT_MESH[0]``).
_ACT_MESH = [None]


def set_activation_mesh(mesh):
    """Activate (or with ``None`` deactivate) ``constrain`` for model code
    traced after this call."""
    _ACT_MESH[0] = mesh


def _data_axes(mesh):
    """Mesh axes that carry batch-parallel / FSDP work, mesh order."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _collapse(axes):
    """() -> None, (a,) -> a, longer tuples unchanged (PartitionSpec
    equality distinguishes ``"data"`` from ``("data",)``)."""
    if not axes:
        return None
    if isinstance(axes, str):
        return axes
    return axes[0] if len(axes) == 1 else tuple(axes)


def default_rules(mesh, cfg=None):
    """Logical-axis -> mesh-axis table for ``mesh``.

    ``cfg.sharding_overrides`` (``((logical, mesh_axis_or_None), ...)``)
    rewrites individual entries — the hillclimb lever for e.g. attn-DP or
    expert-parallel variants.  Axes absent from the mesh map to None.
    """
    names = tuple(mesh.axis_names)
    data = _collapse(_data_axes(mesh))
    model = "model" if "model" in names else None
    rules = {
        "batch": data,
        "embed": data,        # FSDP: weights sharded over all data axes
        "vocab": model,
        "heads": model,
        "kv": model,
        "ff": model,
        "ssm_inner": model,
        "ssm_heads": model,
        "experts": None,      # dense_dp default: experts replicated
        "layers": None,       # scan dimension, never sharded
    }
    if cfg is not None:
        for logical, axis in getattr(cfg, "sharding_overrides", ()) or ():
            rules[logical] = axis
    return rules


def _place(assignment, dim, mesh, used):
    """One spec entry: ``assignment`` if it is a known, unconsumed mesh
    axis (or tuple) whose extent divides ``dim``, else None (replicate)."""
    if assignment is None:
        return None
    flat = (assignment,) if isinstance(assignment, str) else tuple(assignment)
    if any(a not in mesh.axis_names for a in flat):
        return None
    if any(a in used for a in flat):
        return None
    extent = int(np.prod([mesh.shape[a] for a in flat]))
    if extent <= 0 or dim % extent != 0:
        return None
    used.update(flat)
    return assignment


def spec_for(axes, shape, mesh, rules):
    """PartitionSpec for one tensor from its logical ``axes`` tuple.

    Guards: non-divisible dims are replicated, and each mesh axis is
    consumed at most once (first logical axis mapped to it wins).
    """
    assert len(axes) <= len(shape), \
        f"more logical axes {axes} than dims {shape}"
    used = set()
    entries = []
    for ax, dim in zip(axes, shape):
        assignment = rules.get(ax) if ax is not None else None
        entries.append(_place(assignment, int(dim), mesh, used))
    return P(*entries)


def batch_spec(mesh):
    """[batch, seq] layout: batch over all data axes, seq replicated."""
    return P(_collapse(_data_axes(mesh)), None)


def graph_spec(mesh):
    """PPM graph arrays: the device dimension over ALL mesh axes flattened
    (the bin exchange treats the pod mesh as one flat all_to_all group)."""
    return P(tuple(mesh.axis_names))


def constrain(x, *entries):
    """``with_sharding_constraint`` via logical names, guarded.

    ``entries`` name one spec entry per leading dim of ``x``: ``"batch"``
    (the data axes), a literal mesh axis name/tuple, or None.  Dims whose
    extent does not divide, axes already consumed, and axes missing from
    the active mesh all fall back to replicated — the guard never errors.
    A no-op until ``set_activation_mesh`` installs a mesh (single-device
    tests, shard_map bodies).
    """
    mesh = _ACT_MESH[0]
    if mesh is None:
        return x
    used = set()
    spec = []
    for dim, e in zip(x.shape, entries):
        if e == "batch":
            e = _collapse(_data_axes(mesh))
        spec.append(_place(e, int(dim), mesh, used))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def param_shardings(axes_tree, params, mesh, rules=None):
    """NamedSharding tree for a params tree from its logical-axes twin.

    ``params`` leaves only need ``.shape`` (arrays or ShapeDtypeStructs).
    ``rules`` defaults to ``default_rules(mesh)``; pass an amended dict for
    variants (e.g. ZeRO-1 drops the ``embed`` FSDP rule for compute params).
    """
    if rules is None:
        rules = default_rules(mesh)

    def is_axes_leaf(x):
        return isinstance(x, tuple) and all(
            e is None or isinstance(e, str) for e in x)

    def one(axes, p):
        return NamedSharding(mesh, spec_for(axes, p.shape, mesh, rules))

    return jax.tree_util.tree_map(one, axes_tree, params,
                                  is_leaf=is_axes_leaf)
