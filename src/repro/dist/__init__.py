"""Multi-device machinery: sharding rules, JAX-compat shims, PPM engine.

GPOP executes graph algorithms as partition-parallel BSP supersteps
(paper §3; DESIGN.md §2), and each superstep maps onto the mesh like so:

  Scatter   every partition streams its active vertices' messages into
            per-destination-partition bins — local, cache-resident writes
            on whichever device owns the partition;
  Sync      the bin exchange, the superstep's only communication: one
            ``all_to_all`` over ALL mesh axes flattened into a single
            device group (``sharding.graph_spec`` lays the graph's
            partition dimension over the full axis tuple, so a 2x16x16
            pod mesh is one 512-way exchange);
  Gather    every partition folds the bins it owns with the app monoid —
            again local to the owning device.

The LM stack reuses the same mesh with named roles instead of the flat
group: ``pod``/``data`` axes carry batch-parallel + FSDP work and
``model`` carries tensor-parallel shards (``sharding.default_rules``).

Modules:
  compat    version shims (AxisType, AbstractMesh, make_mesh, shard_map)
            installed into ``jax``/``jax.sharding`` on import;
  sharding  logical-axis -> mesh-axis rules, spec construction, activation
            constraints, whole-tree param shardings;
  engine    ``DistEngine`` — the multi-device PPM engine itself.
"""
from . import compat  # noqa: F401  (installs the version shims)
from .sharding import (batch_spec, constrain, default_rules, graph_spec,
                       param_shardings, set_activation_mesh, spec_for)

__all__ = ["compat", "batch_spec", "constrain", "default_rules",
           "graph_spec", "param_shardings", "set_activation_mesh",
           "spec_for"]
