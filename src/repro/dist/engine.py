"""Distributed PPM engine: shard_map + all_to_all over the device mesh.

The BSP structure of the paper maps 1:1 onto collectives (DESIGN.md §2):

  Scatter (per device, local)   -> message buffer out[D, S] (DC) or
                                   ragged compaction (SC)
  barrier + bin exchange        -> all_to_all / ragged_all_to_all
  Gather (per device, local)    -> blocked segmented monoid fold over the
                                   statically resident dc_bin adjacency
                                   (registry kernel 'fold': the Pallas
                                   kernel of repro.kernels.fold_block by
                                   default — no jax.ops segment ops)

DC mode sends *values only* (+1 validity byte, see DESIGN.md); SC mode sends
(value, dst-id) pairs with wire bytes proportional to active edges.  Mode
selection: ``mode='hybrid'`` applies the aggregated Eq. 1 model per
iteration; ``mode='hybrid_pp'`` applies it per PARTITION (the paper's exact
granularity) and runs both streams in one superstep.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from ..backend import registry as kregistry
from ..core.engine import _run_batched_loop, _tree_where
from ..core.program import VertexProgram
from .compat import NamedSharding, PartitionSpec as P, shard_map
from .sharding import graph_spec


def _squeeze0(tree):
    return jax.tree_util.tree_map(lambda a: a[0], tree)


# ----------------------------------------------------------------------
# wire compression: what actually crosses the all_to_all
# ----------------------------------------------------------------------

def _pack_bf16_pairs(vals, ident):
    """``[..., S]`` bf16 -> ``[..., ceil(S/2)]`` uint32 wire lanes.

    Two bf16 messages bitcast-packed per u32 lane: XLA sinks plain
    converts through collectives (cancelling the up/down-cast pair, so
    the wire stays f32 — observed on XLA:CPU); bitcasts cannot be
    cancelled, so the wire really carries half the bytes.  Odd ``S`` is
    padded with one identity column first (sliced off after the
    exchange by :func:`_unpack_bf16_pairs`)."""
    S = vals.shape[-1]
    if S % 2:
        pad = jnp.full(vals.shape[:-1] + (1,), ident, vals.dtype)
        vals = jnp.concatenate([vals, pad], axis=-1)
    pairs = vals.reshape(vals.shape[:-1] + ((S + 1) // 2, 2))
    return jax.lax.bitcast_convert_type(pairs, jnp.uint32)


def _unpack_bf16_pairs(packed, S):
    """Inverse of :func:`_pack_bf16_pairs`: ``[..., P]`` u32 -> ``[..., S]``
    bf16 (the odd-S identity pad column is discarded)."""
    v = jax.lax.bitcast_convert_type(packed, jnp.bfloat16)
    return v.reshape(v.shape[:-2] + (-1,))[..., :S]


def _pack_bits(flags):
    """``[..., S]`` bool -> ``[..., ceil(S/8)]`` uint8 frontier bitmap.

    Validity flags cross the wire 8x smaller than bool lanes (XLA sends
    one byte per bool).  The pack/unpack pair is shifts and masked sums,
    which the algebraic simplifier cannot cancel through the collective,
    so the wire really carries the packed bytes."""
    S = flags.shape[-1]
    Sp = -(-S // 8) * 8
    if Sp != S:
        pad = jnp.zeros(flags.shape[:-1] + (Sp - S,), jnp.bool_)
        flags = jnp.concatenate([flags, pad], axis=-1)
    bits = flags.reshape(flags.shape[:-1] + (Sp // 8, 8)).astype(jnp.uint8)
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    return jnp.sum(bits * weights, axis=-1, dtype=jnp.uint8)


def _unpack_bits(packed, S):
    """Inverse of :func:`_pack_bits`: ``[..., P]`` u8 -> ``[..., S]`` bool."""
    bits = (packed[..., None] >> jnp.arange(8, dtype=jnp.uint8)) \
        & jnp.uint8(1)
    return bits.reshape(bits.shape[:-2] + (-1,))[..., :S] != 0


def dc_wire_bytes(meta: dict, value_itemsize: int, *,
                  compressed: bool = False, wire_bitmap: bool = True,
                  dense_frontier: bool = False, batch: int = 1) -> int:
    """Per-step, per-device all_to_all payload bytes of the DC bin
    exchange (values + validity flags), for benchmark/cost reporting.

    ``compressed`` means the bf16 wire is actually active (``wire_bf16``
    requested AND the monoid is f32); ``batch`` scales both payloads by
    the live lane width of a batched step."""
    S, D = meta["S"], meta["D"]
    if compressed:
        val = D * (S + (S % 2)) * 2          # u32 lanes, 2 bf16 each
    else:
        val = D * S * value_itemsize
    if dense_frontier:
        flags = 0
    else:
        flags = D * (-(-S // 8) if wire_bitmap else S)
    return batch * (val + flags)


def _fold_lanes(fold, vals, valid, ids, ns):
    """Per-lane segmented fold, unrolled over the lane axis at trace time.

    The registry folds have no vmap batching rule (XLA's default scatter
    batching serializes ~100x on CPU), and flattening lanes into one
    ``lane * ns + id`` segment space is QUADRATIC in B for the blocked
    fold — every message block carries a full ``[num_segments]`` partial
    accumulator, and both the block count and the segment count grow
    with B (measured 5x slower than B sequential folds at B=16).  The
    unroll keeps per-lane cost identical to the sequential fold —
    batching amortizes the collectives and host dispatch, never the fold
    math — at B extra traced ops per compiled step (bounded: one step
    per pow2 lane width ever compiles)."""
    accs, touch = [], []
    for i in range(vals.shape[0]):
        a, t = fold(vals[i], valid[i], ids[i], ns)
        accs.append(a)
        touch.append(t)
    return jnp.stack(accs), jnp.stack(touch)


def _resolve_fold(program: VertexProgram, backend=None, tile=None, q=None):
    """Shard-local segmented fold through the backend registry.

    Defaults to the blocked Pallas fold — Mosaic on TPU, interpreted
    elsewhere; :mod:`repro.kernels.fold_block` up to
    ``REPRO_FOLD_MAX_SEGMENTS`` per-device segments and the two-level
    :mod:`repro.kernels.fold_two_level` (bucket width ``q``) beyond —
    which traces cleanly inside the shard_map step bodies; monoids
    outside the Pallas set (e.g. the packed uint64 ``min_with_payload``)
    fall back to ``ref`` per call."""
    b = kregistry.resolve("fold", program.monoid, choice=backend)
    fold = b.segment_fold(program.monoid, tile=tile, q=q)
    return kregistry._tag_scope(fold, "fold", b.name), b.name


def _resolve_fused(program: VertexProgram, backend=None, tile=None, q=None):
    """Shard-local fused gather→fold (registry kernel ``fused_dc``), or
    ``(None, None)`` when the composed slot-gather + fold path should run.

    Mirrors :func:`_resolve_fold`'s selection (explicit ``backend=``, the
    ``REPRO_KERNEL_BACKEND`` env, platform default) but with the fused
    kernel's fallback rule: no per-call ``ref`` substitution — when
    ``REPRO_FUSED=0`` or the selected backend does not lower the
    ``(monoid, dtype)`` combination, the DC gather silently stays on the
    composed path (which also remains the SC/hybrid lowering)."""
    from ..kernels.fused_step import fused_enabled
    if not fused_enabled():
        return None, None
    mono = program.monoid
    platform = jax.default_backend()
    if backend is None:
        b = kregistry.BACKENDS[
            kregistry.default_backend_name(platform, "fused_dc")]
    elif isinstance(backend, str):
        if backend not in kregistry.BACKENDS:
            raise ValueError(
                f"unknown backend {backend!r}; choose one of "
                f"{kregistry.available_backends()}")
        b = kregistry.BACKENDS[backend]
    else:
        b = backend
    if not b.supports(platform, "fused_dc", mono.name, mono.dtype):
        return None, None
    fk = b.fused_stream(mono, tile=tile, q=q)
    return kregistry._tag_scope(fk, "fused_dc", b.name), b.name


def build_dc_step(program: VertexProgram, meta: dict,
                  axis_names: Sequence[str], dense_frontier: bool = False,
                  wire_bf16: bool = False, wire_bitmap: bool = False,
                  fold=None, fused=None, batched: bool = False):
    """Destination-centric distributed iteration (per-device body).

    dense_frontier: the app keeps every vertex active every iteration
    (paper's PageRank) — the validity-flag exchange is constant and is
    skipped entirely, halving the small-payload side of the bin exchange.
    wire_bf16: cast f32 message values to bf16 on the wire (beyond-paper
    message compression; a no-op — hence exact — for the integer id
    monoids of BFS/CC, approximate for float accumulations).  Odd ``S``
    is handled by padding the packed lane to even length.
    wire_bitmap: exchange the validity flags as a packed frontier bitmap
    (8x smaller than bool lanes on the wire, bit-exact).
    batched: the body carries a leading query-lane axis — state/active
    arrive as ``[B, nv]`` shards, the bin exchange moves ``[B, D, S]`` in
    ONE collective per payload, and the gather folds every lane through a
    single flattened-segment-space fold (:func:`_fold_lanes`), so each
    scatter/all_to_all/fold launch is amortized across the whole batch.
    fused: a registry ``fused_dc`` stream kernel (:func:`_resolve_fused`);
    when set, the gather side skips the ``[NEd]`` slot-gathered
    edge-value stream entirely — the kernel gathers straight from the
    received bin table and folds in one launch.  ``None`` keeps the
    composed slot gather + fold."""
    mono = program.monoid
    nv, S, D = meta["nv"], meta["S"], meta["D"]
    weighted = meta["weighted"]
    axes = tuple(axis_names)
    compress = wire_bf16 and mono.dtype == jnp.float32
    fold = fold if fold is not None else _resolve_fold(program)[0]
    # wire dtype used end-to-end from scatter through the gather-side slot
    # lookup: adjacent up/down-cast pairs around the collective get
    # cancelled by XLA's algebraic simplifier (observed), so the narrow
    # dtype must live across the whole exchange
    wdt = jnp.bfloat16 if compress else mono.dtype
    # all_to_all split/concat axis: the [D] bin axis sits after the
    # optional lane axis
    dev_ax = 1 if batched else 0

    def vm(fn, in_axes):
        return jax.vmap(fn, in_axes=in_axes) if batched else fn

    def step(state, active, arrays, it):
        # state/active: [nv] shard ([B, nv] when batched); arrays:
        # per-device slices (leading 1)
        A = _squeeze0(arrays)
        lead = active.shape[:-1]                              # () or (B,)
        msgs = vm(program.scatter_fn, 0)(state).astype(wdt)
        ident = jnp.asarray(mono.identity, wdt)

        if program.init_fn is not None:
            st2, keep = vm(program.init_fn, (0, None))(state, it)
            state = _tree_where(active, st2, state)
            keep = keep & active
        else:
            keep = jnp.zeros(active.shape, jnp.bool_)

        # ---- scatter: fill the bin row (values only) ----
        srcl = A["out_src_local"]                             # [D, S]
        flag = A["out_valid"] & active[..., srcl]             # [.., D, S]
        out_vals = jnp.where(flag, msgs[..., srcl], ident)

        # ---- bin exchange (the BSP barrier) ----
        if compress:
            packed = _pack_bf16_pairs(out_vals, ident)
            recv_p = jax.lax.all_to_all(packed, axes, dev_ax, dev_ax)
            recv_vals = _unpack_bf16_pairs(recv_p, S)
        else:
            recv_vals = jax.lax.all_to_all(out_vals, axes, dev_ax, dev_ax)
        if dense_frontier:
            # validity is static (= out_valid of the sender); the receive
            # side's static in_valid already encodes it
            rf = jnp.ones(lead + (D * S + 1,), jnp.bool_) \
                .at[..., -1].set(False)
        else:
            if wire_bitmap:
                recv_pk = jax.lax.all_to_all(
                    _pack_bits(flag), axes, dev_ax, dev_ax)
                recv_flag = _unpack_bits(recv_pk, S)
            else:
                recv_flag = jax.lax.all_to_all(flag, axes, dev_ax, dev_ax)
            rf = jnp.concatenate(
                [recv_flag.reshape(lead + (D * S,)),
                 jnp.zeros(lead + (1,), jnp.bool_)], axis=-1)
        rv = jnp.concatenate(
            [recv_vals.reshape(lead + (D * S,)),
             jnp.full(lead + (1,), ident, wdt)], axis=-1)

        # ---- gather over the pre-written dc_bin ----
        if fused is not None:
            # fused lowering: the kernel gathers each edge's value from
            # the received bin table itself — no [NEd] edge-value stream.
            # The table is pre-cast off the wire dtype (the elementwise
            # cast commutes with the gather, so parity with the composed
            # ``rv[slot].astype`` is bit-exact)
            table = rv.astype(mono.dtype)
            aw = (program.apply_weight
                  if program.apply_weight is not None and weighted
                  else None)
            w = A["in_w"] if aw is not None else None
            slot, evalid_s = A["in_msg_slot"], A["in_valid"]
            dst_s = A["in_dst_local"]
            if batched:
                # per-lane unroll, same rationale as _fold_lanes (the
                # static slot/validity/dst streams are shared)
                accs, touch = [], []
                for i in range(table.shape[0]):
                    a, t = fused(table[i], rf[i], slot, evalid_s, dst_s,
                                 nv + 1, w=w, apply_weight=aw)
                    accs.append(a)
                    touch.append(t)
                acc, touched = jnp.stack(accs), jnp.stack(touch)
            else:
                acc, touched = fused(table, rf, slot, evalid_s, dst_s,
                                     nv + 1, w=w, apply_weight=aw)
        else:
            slot = A["in_msg_slot"]
            ev = rv[..., slot].astype(mono.dtype)             # [.., NEd]
            evalid = rf[..., slot] & A["in_valid"]
            if program.apply_weight is not None and weighted:
                ev = vm(program.apply_weight, (0, None))(ev, A["in_w"])
            ev = jnp.where(evalid, ev, mono.identity)
            dst = jnp.where(evalid, A["in_dst_local"], nv)
            if batched:
                acc, touched = _fold_lanes(fold, ev, evalid, dst, nv + 1)
            else:
                acc, touched = fold(ev, evalid, dst, nv + 1)
        acc, touched = acc[..., :nv], touched[..., :nv]

        st3, activated = vm(program.apply_fn, (0, 0, 0, None))(
            state, acc, touched, it)
        state = _tree_where(touched, st3, state)
        new_active = keep | (activated & touched)
        if program.filter_fn is not None:
            st4, fkeep = vm(program.filter_fn, (0, None))(state, it)
            state = _tree_where(new_active, st4, state)
            new_active = new_active & fkeep
        return state, new_active

    return step


def build_sc_step(program: VertexProgram, meta: dict,
                  axis_names: Sequence[str], ragged: bool = False,
                  fold=None):
    """Source-centric distributed iteration: per-destination compaction +
    ragged exchange.

    ``ragged=True`` uses ``lax.ragged_all_to_all`` (TPU backends — wire bytes
    truly proportional to the active edges).  ``ragged=False`` is the portable
    emulation: compacted per-pair capacity buffers over a dense ``all_to_all``
    with explicit counts (identical semantics; XLA:CPU has no ragged thunk).
    The Eq. 1 cost model prices the SC wire bytes as ragged either way, which
    is exact for the TPU target.
    """
    mono = program.monoid
    nv, D = meta["nv"], meta["D"]
    cap_in = meta["cap_in"]
    cap_pair = meta["cap_pair"]
    weighted = meta["weighted"]
    axes = tuple(axis_names)
    fold = fold if fold is not None else _resolve_fold(program)[0]

    def step(state, active, arrays, it):
        A = _squeeze0(arrays)
        msgs = program.scatter_fn(state).astype(mono.dtype)
        ident = mono.identity
        ne_s = A["oe_src_local"].shape[0]

        if program.init_fn is not None:
            st2, keep = program.init_fn(state, it)
            state = _tree_where(active, st2, state)
            keep = keep & active
        else:
            keep = jnp.zeros((nv,), jnp.bool_)

        # ---- compact active out-edges per destination-device group ----
        act_e = A["oe_valid"] & active[A["oe_src_local"]]      # [NEs]
        vals_e = msgs[A["oe_src_local"]]
        if program.apply_weight is not None and weighted:
            vals_e = program.apply_weight(vals_e, A["oe_w"])
        goff = A["oe_group_off"].astype(jnp.int32)             # [D+1]
        c = jnp.cumsum(act_e.astype(jnp.int32))
        co = jnp.concatenate([jnp.zeros((1,), jnp.int32), c])
        tot_at = co[goff]                                      # [D+1]
        send_sizes = jnp.diff(tot_at)                          # [D]
        grp = jnp.searchsorted(goff[1:], jnp.arange(ne_s, dtype=jnp.int32),
                               side="right").astype(jnp.int32)
        grp_c = jnp.minimum(grp, D - 1)
        rank = (c - 1) - tot_at[grp_c]                         # rank in group

        if ragged:
            send_off = jnp.concatenate(
                [jnp.zeros((1,), jnp.int32),
                 jnp.cumsum(send_sizes)[:-1].astype(jnp.int32)])
            pos = jnp.where(act_e, send_off[grp_c] + rank, ne_s)
            buf_vals = jnp.full((ne_s + 1,), ident, mono.dtype) \
                .at[pos].set(jnp.where(act_e, vals_e, ident))[:ne_s]
            buf_ids = jnp.full((ne_s + 1,), nv, jnp.int32) \
                .at[pos].set(jnp.where(act_e, A["oe_dst_local"], nv))[:ne_s]
            recv_sizes = jax.lax.all_to_all(
                send_sizes.reshape(D, 1), axes, 0, 0).reshape(D)
            recv_off = jnp.concatenate(
                [jnp.zeros((1,), jnp.int32),
                 jnp.cumsum(recv_sizes)[:-1].astype(jnp.int32)])
            out_offsets = jax.lax.all_to_all(
                recv_off.reshape(D, 1), axes, 0, 0).reshape(D)
            rvals = jax.lax.ragged_all_to_all(
                buf_vals, jnp.full((cap_in,), ident, mono.dtype),
                send_off, send_sizes, out_offsets, recv_sizes,
                axis_name=axes)
            rids = jax.lax.ragged_all_to_all(
                buf_ids, jnp.full((cap_in,), nv, jnp.int32),
                send_off, send_sizes, out_offsets, recv_sizes,
                axis_name=axes)
            total = jnp.sum(recv_sizes)
            valid = jnp.arange(cap_in, dtype=jnp.int32) < total
        else:
            # portable emulation: per-pair rows of capacity cap_pair
            flat = jnp.where(act_e, grp_c * cap_pair + rank, D * cap_pair)
            buf_vals = jnp.full((D * cap_pair + 1,), ident, mono.dtype) \
                .at[flat].set(jnp.where(act_e, vals_e, ident))[:-1] \
                .reshape(D, cap_pair)
            buf_ids = jnp.full((D * cap_pair + 1,), nv, jnp.int32) \
                .at[flat].set(jnp.where(act_e, A["oe_dst_local"], nv))[:-1] \
                .reshape(D, cap_pair)
            recv_sizes = jax.lax.all_to_all(
                send_sizes.reshape(D, 1), axes, 0, 0).reshape(D)
            rvals = jax.lax.all_to_all(buf_vals, axes, 0, 0).reshape(-1)
            rids = jax.lax.all_to_all(buf_ids, axes, 0, 0).reshape(-1)
            col = jnp.tile(jnp.arange(cap_pair, dtype=jnp.int32), (D, 1))
            valid = (col < recv_sizes[:, None]).reshape(-1)

        ids = jnp.where(valid, rids, nv)
        vals = jnp.where(valid, rvals, ident)
        acc, touched = fold(vals, valid, ids, nv + 1)
        acc, touched = acc[:nv], touched[:nv]

        st3, activated = program.apply_fn(state, acc, touched, it)
        state = _tree_where(touched, st3, state)
        new_active = keep | (activated & touched)
        if program.filter_fn is not None:
            st4, fkeep = program.filter_fn(state, it)
            state = _tree_where(new_active, st4, state)
            new_active = new_active & fkeep
        return state, new_active

    return step


def build_hybrid_step(program: VertexProgram, meta: dict,
                      axis_names: Sequence[str], fold=None):
    """Per-partition dual-mode iteration — the paper's exact granularity
    (Eq. 1 decided per partition, not per iteration).

    ``dc_mask`` (one bool per local partition) selects, per partition,
    whether its vertices scatter through the dense DC bins or the compacted
    SC exchange; both streams fold into the same accumulator, exactly like
    the single-device engine."""
    mono = program.monoid
    nv, S, D = meta["nv"], meta["S"], meta["D"]
    cap_pair = meta["cap_pair"]
    kpd = meta["kpd"]
    q = nv // kpd
    weighted = meta["weighted"]
    axes = tuple(axis_names)
    fold = fold if fold is not None else _resolve_fold(program)[0]

    def step(state, active, arrays, it, dc_mask):
        A = _squeeze0(arrays)
        dcm = dc_mask[0] if dc_mask.ndim == 2 else dc_mask     # [kpd]
        msgs = program.scatter_fn(state).astype(mono.dtype)
        ident = mono.identity

        if program.init_fn is not None:
            st2, keep = program.init_fn(state, it)
            state = _tree_where(active, st2, state)
            keep = keep & active
        else:
            keep = jnp.zeros((nv,), jnp.bool_)

        # ---- DC stream: only partitions in DC mode ----
        srcl = A["out_src_local"]                              # [D, S]
        src_part = srcl // q
        flag = A["out_valid"] & active[srcl] & dcm[src_part]
        out_vals = jnp.where(flag, msgs[srcl], ident)
        recv_vals = jax.lax.all_to_all(out_vals, axes, 0, 0)
        recv_flag = jax.lax.all_to_all(flag, axes, 0, 0)
        rv = jnp.concatenate([recv_vals.reshape(-1),
                              mono.identity_array((1,))])
        rf = jnp.concatenate([recv_flag.reshape(-1),
                              jnp.zeros((1,), jnp.bool_)])
        ev = rv[A["in_msg_slot"]]
        evalid = rf[A["in_msg_slot"]] & A["in_valid"]
        if program.apply_weight is not None and weighted:
            ev = program.apply_weight(ev, A["in_w"])
        ev = jnp.where(evalid, ev, ident)
        dst = jnp.where(evalid, A["in_dst_local"], nv)
        acc, touched = fold(ev, evalid, dst, nv + 1)

        # ---- SC stream: active vertices of non-DC partitions ----
        vpart = jnp.arange(nv, dtype=jnp.int32) // q
        sc_active = active & ~dcm[vpart]
        ne_s = A["oe_src_local"].shape[0]
        act_e = A["oe_valid"] & sc_active[A["oe_src_local"]]
        vals_e = msgs[A["oe_src_local"]]
        if program.apply_weight is not None and weighted:
            vals_e = program.apply_weight(vals_e, A["oe_w"])
        goff = A["oe_group_off"].astype(jnp.int32)
        c = jnp.cumsum(act_e.astype(jnp.int32))
        co = jnp.concatenate([jnp.zeros((1,), jnp.int32), c])
        tot_at = co[goff]
        send_sizes = jnp.diff(tot_at)
        grp = jnp.searchsorted(goff[1:], jnp.arange(ne_s, dtype=jnp.int32),
                               side="right").astype(jnp.int32)
        grp_c = jnp.minimum(grp, D - 1)
        rank = (c - 1) - tot_at[grp_c]
        flat = jnp.where(act_e, grp_c * cap_pair + rank, D * cap_pair)
        buf_vals = jnp.full((D * cap_pair + 1,), ident, mono.dtype) \
            .at[flat].set(jnp.where(act_e, vals_e, ident))[:-1] \
            .reshape(D, cap_pair)
        buf_ids = jnp.full((D * cap_pair + 1,), nv, jnp.int32) \
            .at[flat].set(jnp.where(act_e, A["oe_dst_local"], nv))[:-1] \
            .reshape(D, cap_pair)
        recv_sizes = jax.lax.all_to_all(
            send_sizes.reshape(D, 1), axes, 0, 0).reshape(D)
        rvals = jax.lax.all_to_all(buf_vals, axes, 0, 0).reshape(-1)
        rids = jax.lax.all_to_all(buf_ids, axes, 0, 0).reshape(-1)
        col = jnp.tile(jnp.arange(cap_pair, dtype=jnp.int32), (D, 1))
        valid = (col < recv_sizes[:, None]).reshape(-1)
        ids = jnp.where(valid, rids, nv)
        vals = jnp.where(valid, rvals, ident)
        acc2, touched2 = fold(vals, valid, ids, nv + 1)

        acc = mono.combine(acc, acc2)[:nv]
        touched = (touched | touched2)[:nv]

        st3, activated = program.apply_fn(state, acc, touched, it)
        state = _tree_where(touched, st3, state)
        new_active = keep | (activated & touched)
        if program.filter_fn is not None:
            st4, fkeep = program.filter_fn(state, it)
            state = _tree_where(new_active, st4, state)
            new_active = new_active & fkeep
        return state, new_active

    return step


class DistEngine:
    """Multi-device PPM engine over an arbitrary mesh.

    The graph's device dimension is sharded over *all* mesh axes (the PPM
    bin exchange treats the pod mesh as one flat all_to_all group; the pod
    axis simply contributes the slowest-varying device blocks).
    """

    def __init__(self, sharded, program: VertexProgram, mesh,
                 mode: str = "hybrid", bw_ratio: float = 2.0,
                 backend=None, wire_bf16: bool = False,
                 wire_bitmap: bool = True):
        self.sl = sharded
        self.program = program
        self.mesh = mesh
        self.mode = mode
        self.bw_ratio = bw_ratio
        self.axes = tuple(mesh.axis_names)
        self.wire_bf16 = wire_bf16
        self.wire_bitmap = wire_bitmap
        # bf16 wire only engages for f32 monoids; for the integer id
        # monoids (BFS/CC) it is skipped, so requesting it stays exact
        self.wire_compressed = (wire_bf16
                                and program.monoid.dtype == jnp.float32)
        fold, self.backend_name = _resolve_fold(
            program, backend, tile=getattr(sharded, "fold_tile", None),
            q=getattr(sharded, "fold_q", None))
        fused, self.fused_backend_name = _resolve_fused(
            program, backend, tile=getattr(sharded, "fold_tile", None),
            q=getattr(sharded, "fold_q", None))
        meta = dict(nv=sharded.nv, S=sharded.S, D=sharded.D,
                    cap_in=sharded.cap_in, cap_pair=sharded.cap_pair,
                    kpd=sharded.kpd, weighted=sharded.weighted)
        self.meta = meta
        spec_arr = graph_spec(mesh)
        shard = NamedSharding(mesh, spec_arr)
        self.arrays = jax.tree_util.tree_map(
            lambda a: jax.device_put(jnp.asarray(a), shard),
            self.sl.arrays())
        deg = np.zeros(sharded.D * sharded.nv, np.int32)
        deg[:len(sharded.deg)] = sharded.deg
        self.deg = jax.device_put(jnp.asarray(deg), shard)

        dc_body = build_dc_step(program, meta, self.axes, fold=fold,
                                fused=fused, wire_bf16=wire_bf16,
                                wire_bitmap=wire_bitmap)
        sc_body = build_sc_step(program, meta, self.axes, fold=fold)
        hy_body = build_hybrid_step(program, meta, self.axes, fold=fold)

        def wrap(body):
            def fn(state, active, arrays, it):
                return shard_map(
                    body, mesh=mesh,
                    in_specs=(spec_arr, spec_arr, spec_arr, P()),
                    out_specs=(spec_arr, spec_arr),
                )(state, active, arrays, it)
            return jax.jit(fn)
        self._dc = wrap(dc_body)
        self._sc = wrap(sc_body)

        def hy_fn(state, active, arrays, it, dc_mask):
            return shard_map(
                hy_body, mesh=mesh,
                in_specs=(spec_arr, spec_arr, spec_arr, P(), spec_arr),
                out_specs=(spec_arr, spec_arr),
            )(state, active, arrays, it, dc_mask)
        self._hy = jax.jit(hy_fn)

        # batched DC step: ONE shard_map whose body carries a leading
        # query-lane axis — the bin exchange moves [B, D, S] per
        # collective.  jit's shape cache provides the per-width
        # specializations _run_batched_loop asks for (<= log2(B) of them
        # thanks to the pow2 lane compaction)
        dcb_body = build_dc_step(program, meta, self.axes, fold=fold,
                                 fused=fused, wire_bf16=wire_bf16,
                                 wire_bitmap=wire_bitmap, batched=True)
        bspec = P(None, tuple(mesh.axis_names))
        self._bspec = bspec

        def dcb_fn(states, active, arrays, it):
            done = ~active.any(axis=1)                         # [B]
            new_states, new_active = shard_map(
                dcb_body, mesh=mesh,
                in_specs=(bspec, bspec, spec_arr, P()),
                out_specs=(bspec, bspec),
            )(states, active, arrays, it)
            # freeze converged lanes (cf. Engine._batched_step_fn): an
            # empty frontier is already a no-op for every phase, the
            # explicit freeze makes the contract independent of the
            # program's init/filter behaviour
            keep = ~done
            new_states = _tree_where(keep, new_states, states)
            new_active = new_active & keep[:, None]
            return new_states, new_active
        self._dcb = jax.jit(dcb_fn)

        # per-(global)-partition stats for the Eq. 1 per-partition decision;
        # partitions are index-contiguous q-sized ranges, so the segment
        # reduction is a plain reshape-sum (no segment ops anywhere here)
        k_glob = sharded.D * sharded.kpd
        q = sharded.nv // sharded.kpd
        # overflow-safe accumulation dtype for edge-degree sums: when x64
        # is off, `astype(jnp.int64)` silently means int32 and an active
        # degree sum past 2**31 WRAPS, flipping the Eq. 1 decision.
        # Float never wraps, and its ~1e-7 relative rounding cannot flip
        # a float threshold comparison
        fdt = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
        deg_f = self.deg.astype(fdt)

        @jax.jit
        def _part_stats(active):
            a32 = active.astype(jnp.int32)
            counts = a32.reshape(k_glob, q).sum(axis=1)
            ea = (active.astype(fdt) * deg_f).reshape(k_glob, q).sum(axis=1)
            return counts, ea
        self._pstats = _part_stats
        from ..core.cost import CostModel
        dc_cost = (sharded.part_msgs * 4 + k_glob * 4
                   + 2 * sharded.part_msgs * 4 + sharded.part_edges * 4)
        kk = len(sharded.part_edges)
        # pad per-partition constants to the padded global partition count
        dcc = np.zeros(k_glob); dcc[:kk] = dc_cost
        r = sharded.part_msgs / np.maximum(sharded.part_edges, 1)
        scc = np.zeros(k_glob); scc[:kk] = 2 * r * 4 + 3 * 4
        self._cost_pp = CostModel(dc_cost=dcc, sc_coeff=scc,
                                  bw_ratio=bw_ratio)

        @jax.jit
        def _stats(active):
            # vertex count fits int32 (n < 2**31); the edge-degree sum
            # does not — accumulate it in float (see fdt above)
            return (jnp.sum(active.astype(jnp.int32)),
                    jnp.sum(active.astype(fdt) * deg_f))
        self._stats = _stats

        # aggregated Eq. 1 threshold: average DC cost per (all) edge vs the
        # per-active-edge SC cost
        L_edges = float(sharded.part_edges.sum())
        self._dc_total = float(
            (sharded.part_msgs.sum() * 4 + sharded.part_edges.sum() * 4
             + 2 * sharded.part_msgs.sum() * 4))
        r = float(sharded.part_msgs.sum()) / max(L_edges, 1.0)
        self._sc_per_edge = 2 * r * 4 + 3 * 4

    def _choose_dc(self, e_active: float) -> bool:
        if self.mode == "dc":
            return True
        if self.mode == "sc":
            return False
        return self._dc_total <= self.bw_ratio * e_active * self._sc_per_edge

    def run(self, state, frontier, max_iters: int = 10_000,
            until_empty: bool = True):
        shard = NamedSharding(self.mesh, graph_spec(self.mesh))
        state = jax.tree_util.tree_map(
            lambda a: jax.device_put(jnp.asarray(a), shard), state)
        active = jax.device_put(jnp.asarray(frontier, jnp.bool_), shard)
        stats = []
        for it in range(max_iters):
            n_act, e_act = self._stats(active)
            n_act, e_act = int(n_act), float(e_act)
            if until_empty and n_act == 0:
                break
            t0 = time.perf_counter()
            if self.mode == "hybrid_pp":
                counts, ea = self._pstats(active)
                counts = np.asarray(counts)
                ea = np.asarray(ea)
                dc_mask = self._cost_pp.choose_dc(ea, counts > 0)
                state, active = self._hy(
                    state, active, self.arrays, jnp.int32(it),
                    jax.device_put(
                        jnp.asarray(dc_mask),
                        NamedSharding(self.mesh, graph_spec(self.mesh))))
                jax.block_until_ready(active)
                # analytic wire: full DC bin payload for the DC stream +
                # per-active-edge SC payload of the SC partitions
                sc_e = float(ea[(~dc_mask) & (counts > 0)].sum())
                wire = (self.wire_bytes_per_step()
                        + int(self._sc_per_edge * sc_e))
                stats.append(dict(it=it, n_active=n_act, e_active=int(e_act),
                                  mode="hybrid_pp",
                                  dc_parts=int(dc_mask.sum()),
                                  sc_parts=int(((~dc_mask)
                                                & (counts > 0)).sum()),
                                  wire_bytes=wire,
                                  wall_s=time.perf_counter() - t0))
                self._record_iter(stats[-1])
                continue
            use_dc = self._choose_dc(e_act)
            fn = self._dc if use_dc else self._sc
            state, active = fn(state, active, self.arrays, jnp.int32(it))
            jax.block_until_ready(active)
            wire = (self.wire_bytes_per_step() if use_dc
                    else int(self._sc_per_edge * e_act))
            stats.append(dict(it=it, n_active=n_act, e_active=int(e_act),
                              mode="dc" if use_dc else "sc",
                              wire_bytes=wire,
                              wall_s=time.perf_counter() - t0))
            self._record_iter(stats[-1])
        return state, active, stats

    def _record_iter(self, s: dict):
        """Telemetry for one distributed step (no-op when obs is off):
        engine_iter event with the analytic wire bytes, step-wall
        histogram keyed by mode, and an Eq. 1 cost sample."""
        if not obs.enabled():
            return
        prog = self.program.name
        obs.event("engine_iter", engine="dist", program=prog, **s)
        obs.observe("engine.step_wall_s", s["wall_s"], engine="dist",
                    program=prog or "?", mode=s["mode"])
        obs.cost_sample(s["mode"], s["e_active"], s["wall_s"], it=s["it"],
                        engine="dist", program=prog,
                        wire_bytes=s["wire_bytes"])

    # ------------------------------------------------------------------
    def wire_bytes_per_step(self, batch: int = 1) -> int:
        """Analytic per-device all_to_all payload bytes of one DC step
        (values + validity flags) under this engine's wire config, for a
        live lane width of ``batch``."""
        return dc_wire_bytes(
            self.meta, np.dtype(self.program.monoid.dtype).itemsize,
            compressed=self.wire_compressed, wire_bitmap=self.wire_bitmap,
            batch=batch)

    def run_batched(self, states, frontiers, max_iters: int = 10_000,
                    until_empty: bool = True, collect_stats: bool = True):
        """Batched multi-source execution across the mesh: B independent
        queries of the same vertex program advance together through one
        batched DC superstep — the bin exchange moves ``[B, D, S]`` in a
        single all_to_all per payload and the gather folds every lane in
        one flattened-segment fold, so each collective/fold launch is
        amortized across the whole batch.

        ``states`` leaves carry a leading query axis ``[B, ...]``;
        ``frontiers`` is ``[B, D*nv]`` bool over the same global vertex
        space :meth:`run` uses (``D*nv == n_pad``, so the single-device
        ``*_multi`` app entry points work unchanged).  The union frontier
        drives convergence, converged lanes are frozen in-step and
        compacted out between steps at pow2 widths (shared loop:
        :func:`repro.core.engine._run_batched_loop`).  DC mode only —
        batching amortizes launches, while the SC wire advantage shrinks
        as the batched bins fill; the wire blowup is attacked with
        ``wire_bf16`` + the packed frontier bitmap instead.  Results are
        bit-exact with B sequential :meth:`run` calls in ``mode='dc'``
        under the same wire config."""
        shard = NamedSharding(self.mesh, self._bspec)
        states = jax.tree_util.tree_map(
            lambda a: jax.device_put(jnp.asarray(a), shard), states)
        active = jax.device_put(jnp.asarray(frontiers, jnp.bool_), shard)
        assert active.ndim == 2, "frontiers must be [B, D*nv]"

        def step_for_width(W):
            return lambda s, a, it: self._dcb(s, a, self.arrays, it)

        return _run_batched_loop(step_for_width, states, active,
                                 max_iters, until_empty, collect_stats,
                                 engine_name="dist",
                                 program=self.program.name,
                                 wire_bytes_fn=self.wire_bytes_per_step)
