"""Per-cell (arch x input shape) AOT specs: step callable + ShapeDtypeStruct
inputs + in/out shardings.

``input_specs`` follows the brief: weak-type-correct, shardable stand-ins,
no device allocation.  Frontend-stub archs (vlm/audio) receive precomputed
patch/frame embeddings instead of tokens.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import SHAPES, get_config
from ..dist.sharding import (_collapse, _data_axes, batch_spec,
                             default_rules, param_shardings,
                             set_activation_mesh)
from ..models.config import ModelConfig
from ..models.transformer import init_lm, lm_loss
from ..serve.engine import decode_step, init_cache, prefill
from ..train.optimizer import OptConfig, init_opt_state
from ..train.train_step import make_train_step


def _data_extent(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in _data_axes(mesh)]))


def eval_params(cfg: ModelConfig):
    """Shape-only params + logical axes (no allocation)."""
    box = {}

    def f(key):
        p, a = init_lm(cfg, key)
        box["axes"] = a
        return p

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    cdt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    shapes = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, cdt), shapes)
    return shapes, box["axes"]


def _shard_first_divisible(shape, mesh, candidates):
    """PartitionSpec sharding the first (dim, axis) pair that divides."""
    spec = [None] * len(shape)
    used = set()
    for dim_idx, mesh_ax in candidates:
        if mesh_ax is None or dim_idx >= len(shape):
            continue
        flat = tuple(mesh_ax) if isinstance(mesh_ax, (tuple, list)) \
            else (mesh_ax,)
        if any(a in used for a in flat):
            continue
        ext = int(np.prod([mesh.shape[a] for a in flat]))
        if spec[dim_idx] is None and shape[dim_idx] % ext == 0 \
                and shape[dim_idx] >= ext:
            spec[dim_idx] = mesh_ax
            used.update(flat)
    return P(*spec)


def cache_shardings(cfg: ModelConfig, cache_shapes, mesh: Mesh):
    """Shardings for the KV/state cache: batch over data axes when the batch
    divides, otherwise shard the sequence (cache width) over data — the
    sequence-parallel path for batch-1 long-context decode."""
    da = _collapse(_data_axes(mesh))

    def for_leaf(path_key, s):
        shape = s.shape
        if path_key in ("k", "v", "sk", "sv"):
            # [L, B, W, KV, dh]
            return _shard_first_divisible(
                shape, mesh, [(1, da), (2, da), (4, "model"), (3, "model")])
        if path_key == "h":        # [L, B, H, N, P]
            return _shard_first_divisible(
                shape, mesh, [(1, da), (2, "model")])
        if path_key == "conv":     # [L, B, K-1, ch]
            return _shard_first_divisible(
                shape, mesh, [(1, da), (3, "model")])
        if path_key == "pos":      # [B, W]
            return _shard_first_divisible(shape, mesh, [(0, da), (1, da)])
        return P()                 # len etc.

    return {k: NamedSharding(mesh, for_leaf(k, v))
            for k, v in cache_shapes.items()}


def build_cell(arch: str, shape_name: str, mesh: Mesh,
               cfg: Optional[ModelConfig] = None, opt_cfg=None,
               moe_impl: str = "dense_dp", zero1: bool = False):
    """Returns (fn, args tuple of ShapeDtypeStructs, in_shardings,
    out_shardings, meta).

    zero1: ZeRO-1 sharding — optimizer state (master/m/v) keeps full FSDP
    over the data axes, but COMPUTE params drop the data-axis sharding
    (replicated per model-shard).  Trades param memory (bf16 copy
    replicated) for eliminating the per-layer forward/backward weight
    all-gathers; the one gather happens at the optimizer update."""
    cfg = cfg or get_config(arch)
    set_activation_mesh(mesh)
    sh = SHAPES[shape_name]
    S, GB, kind = sh["seq"], sh["batch"], sh["kind"]
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    rules = default_rules(mesh, cfg)
    pshapes, axes = eval_params(cfg)
    if zero1:
        compute_rules = dict(rules, embed=None)
        p_sh = param_shardings(axes, pshapes, mesh, compute_rules)
    else:
        p_sh = param_shardings(axes, pshapes, mesh, rules)
    bspec = batch_spec(mesh)
    rep = NamedSharding(mesh, P())
    dx = _data_extent(mesh)
    meta = dict(arch=arch, shape=shape_name, seq=S, batch=GB, kind=kind)

    if kind == "train":
        opt_cfg = opt_cfg or OptConfig()
        oshapes = jax.eval_shape(
            lambda p: init_opt_state(p, opt_cfg), pshapes)
        opt_p_sh = (param_shardings(axes, pshapes, mesh, rules)
                    if zero1 else p_sh)
        opt_sh = {"m": opt_p_sh, "v": opt_p_sh, "step": rep}
        if "master" in oshapes:
            opt_sh["master"] = opt_p_sh
        # microbatch so the layer-scan residuals (L x B_local x S x d x 2B,
        # the dominant live set under remat) fit the 16 GB HBM with room
        # for params + optimizer + collectives (budget 6 GB)
        b_local = max(GB // dx, 1)
        resid = cfg.n_layers * b_local * S * cfg.d_model * 2 * 2
        microbatches = 1
        while resid / microbatches > 6e9 and microbatches < b_local:
            microbatches *= 2
        if cfg.frontend is not None:
            batch = {"embeds": jax.ShapeDtypeStruct((GB, S, cfg.d_model),
                                                    jnp.float32),
                     "labels": jax.ShapeDtypeStruct((GB, S), jnp.int32)}
            b_sh = {"embeds": NamedSharding(
                        mesh, P(*(tuple(bspec) + (None,)))),
                    "labels": NamedSharding(mesh, bspec)}
        else:
            batch = {"tokens": jax.ShapeDtypeStruct((GB, S), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((GB, S), jnp.int32)}
            b_sh = {k: NamedSharding(mesh, bspec) for k in batch}
        step, _ = make_train_step(cfg, opt_cfg, mesh, moe_impl=moe_impl,
                                  microbatches=microbatches)
        meta["microbatches"] = microbatches
        return (step, (pshapes, oshapes, batch),
                (p_sh, opt_sh, b_sh), (p_sh, opt_sh, rep), meta)

    if kind == "prefill":
        if not cfg.decoder:
            # encoder-only: the serving op is the full-sequence encode
            def encode(params, batch):
                from ..models.transformer import backbone, embed_frontend
                h = embed_frontend(params, cfg, batch["embeds"], dtype)
                pos = jnp.arange(S, dtype=jnp.int32)
                return backbone(params, cfg, h, pos, dtype=dtype,
                                remat=False)
            batch = {"embeds": jax.ShapeDtypeStruct((GB, S, cfg.d_model),
                                                    jnp.float32)}
            b_sh = {"embeds": NamedSharding(
                mesh, P(*(tuple(bspec) + (None,))))}
            out_sh = NamedSharding(mesh, P(*(tuple(bspec) + (None,))))
            return (encode, (pshapes, batch), (p_sh, b_sh), out_sh, meta)
        cshapes = jax.eval_shape(
            lambda: init_cache(cfg, GB, S, dtype))
        c_sh = cache_shardings(cfg, cshapes, mesh)
        if cfg.frontend is not None:
            batch = {"embeds": jax.ShapeDtypeStruct((GB, S, cfg.d_model),
                                                    jnp.float32)}
            b_sh = {"embeds": NamedSharding(
                mesh, P(*(tuple(bspec) + (None,))))}
        else:
            batch = {"tokens": jax.ShapeDtypeStruct((GB, S), jnp.int32)}
            b_sh = {"tokens": NamedSharding(mesh, bspec)}

        def pf(params, batch, cache):
            return prefill(params, cfg, batch, cache, dtype=dtype)

        logit_sh = NamedSharding(mesh, _shard_first_divisible(
            (GB, cfg.vocab), mesh,
            [(0, _data_axes(mesh) or None), (1, "model")]))
        return (pf, (pshapes, batch, cshapes),
                (p_sh, b_sh, c_sh), (logit_sh, c_sh), meta)

    # decode
    cshapes = jax.eval_shape(lambda: init_cache(cfg, GB, S, dtype))
    c_sh = cache_shardings(cfg, cshapes, mesh)
    tokens = jax.ShapeDtypeStruct((GB,), jnp.int32)
    t_sh = NamedSharding(mesh, _shard_first_divisible(
        (GB,), mesh, [(0, _data_axes(mesh) or None)]))

    def dec(params, tokens, cache):
        return decode_step(params, cfg, tokens, cache, dtype=dtype)

    logit_sh = NamedSharding(mesh, _shard_first_divisible(
        (GB, cfg.vocab), mesh,
        [(0, _data_axes(mesh) or None), (1, "model")]))
    return (dec, (pshapes, tokens, cshapes),
            (p_sh, t_sh, c_sh), (logit_sh, c_sh), meta)
