"""Production mesh builders (kept as functions — importing this module never
touches jax device state)."""
from __future__ import annotations

import jax

from ..dist.compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes,
                     axis_types=(AxisType.Auto,) * len(axes))


def make_local_mesh():
    """Whatever this host has (tests / examples)."""
    n = len(jax.devices())
    return make_mesh((n, 1), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)
