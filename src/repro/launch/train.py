"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --steps 100 \
      --ckpt /ckpts/yi6b [--smoke] [--microbatches 4] [--int8-grads]

On a real TPU pod this is the jobset entrypoint (one process per host; jax
distributed init happens from the environment).  Fault tolerance: SIGTERM
triggers a checkpoint before exit; restart with the same --ckpt resumes;
the mesh may differ across restarts (elastic re-sharding in checkpoint.py).
"""
from __future__ import annotations

import argparse
import signal
import sys
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, get_smoke_config
from ..models.transformer import init_lm
from ..train import (DataConfig, OptConfig, TokenPipeline, checkpoint,
                     init_opt_state, jit_train_step, make_train_step)
from .mesh import make_local_mesh, make_production_mesh


class StepWatchdog:
    """Straggler mitigation at the job level: if a step exceeds
    ``factor`` x the trailing median, log it (on real fleets: report the
    slow host for replacement; deterministic data means any restarted
    worker replays identically)."""

    def __init__(self, factor: float = 3.0, window: int = 20):
        self.times, self.factor, self.window = [], factor, window
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        self.times.append(dt)
        hist = sorted(self.times[-self.window:])
        med = hist[len(hist) // 2]
        slow = len(self.times) > 5 and dt > self.factor * med
        self.flagged += int(slow)
        return slow


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--ckpt", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + local mesh (CPU-runnable)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--int8-grads", action="store_true")
    ap.add_argument("--data", default=None, help="binary token file")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    seq = args.seq or (64 if args.smoke else 4096)
    gb = args.global_batch or (8 if args.smoke else 256)
    mesh = (make_local_mesh() if args.smoke
            else make_production_mesh(multi_pod=args.multi_pod))
    print(f"[train] {cfg.name} seq={seq} gb={gb} mesh={dict(mesh.shape)}")

    params, axes = init_lm(cfg, jax.random.PRNGKey(0))
    ocfg = OptConfig(total_steps=args.steps, int8_compress=args.int8_grads,
                     compute_dtype=cfg.dtype)
    opt = init_opt_state(params, ocfg)
    if ocfg.compute_dtype == "bfloat16":
        params = jax.tree_util.tree_map(
            lambda p: p.astype(jnp.bfloat16), params)
    step_fn, sh = make_train_step(cfg, ocfg, mesh, axes, params,
                                  microbatches=args.microbatches)
    jstep = jit_train_step(
        step_fn, sh, batch_keys=("embeds", "labels") if cfg.frontend
        else ("tokens", "labels"))
    pipe = TokenPipeline(DataConfig(
        vocab=cfg.vocab, seq_len=seq, global_batch=gb, seed=0,
        path=args.data,
        embed_dim=cfg.d_model if cfg.frontend else None))

    start = checkpoint.latest_step(args.ckpt) or 0
    if start:
        params, opt, start = checkpoint.restore(args.ckpt, params, opt)
        print(f"[train] resumed at step {start}")

    state = {"params": params, "opt": opt, "step": start}

    def on_term(signum, frame):
        print("[train] SIGTERM: checkpointing before exit")
        checkpoint.save(args.ckpt, state["step"], state["params"],
                        state["opt"])
        sys.exit(0)

    signal.signal(signal.SIGTERM, on_term)
    wd = StepWatchdog()
    for i in range(start, args.steps):
        t0 = time.time()
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        params, opt, m = jstep(params, opt, batch)
        state.update(params=params, opt=opt, step=i + 1)
        dt = time.time() - t0
        if wd.observe(dt):
            print(f"[watchdog] slow step {i}: {dt:.2f}s")
        if i % 10 == 0:
            print(f"step {i:6d} loss {float(m['loss']):.4f} {dt:.2f}s/step")
        if (i + 1) % args.ckpt_every == 0:
            checkpoint.save(args.ckpt, i + 1, params, opt)
    checkpoint.save(args.ckpt, args.steps, params, opt)
    print(f"[train] done ({wd.flagged} straggler steps flagged)")


if __name__ == "__main__":
    main()
