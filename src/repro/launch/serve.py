"""Serving launcher: slot-based continuous batching.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --smoke \
      --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config
from ..dist.sharding import param_shardings, set_activation_mesh
from ..models.transformer import init_lm
from ..serve import Request, Server
from ..train import checkpoint
from .mesh import make_local_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.decoder:
        raise SystemExit(f"{args.arch} is encoder-only; no decode serving")
    params, axes = init_lm(cfg, jax.random.PRNGKey(0))
    dtype = jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16
    if args.ckpt:
        params, _, st = checkpoint.restore(args.ckpt, params, {})
        print(f"[serve] loaded checkpoint step {st}")
    # place params via the sharding-rules layer (FSDP/TP degenerate to
    # replicated on the 1-device smoke mesh) and activate constraints
    mesh = make_local_mesh()
    set_activation_mesh(mesh)
    params = jax.tree_util.tree_map(
        jax.device_put, params, param_shardings(axes, params, mesh))
    srv = Server(params, cfg, n_slots=args.slots, max_len=args.max_len,
                 dtype=dtype)
    rng = np.random.default_rng(0)
    for r in range(args.requests):
        srv.submit(Request(rid=r,
                           prompt=rng.integers(0, cfg.vocab,
                                               rng.integers(4, 16),
                                               dtype=np.int32),
                           max_new=args.max_new))
    t0 = time.time()
    done = srv.run()
    dt = time.time() - t0
    tok = sum(len(d.out) for d in done)
    print(f"[serve] {len(done)} requests, {tok} tokens, {dt:.1f}s "
          f"({tok / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
