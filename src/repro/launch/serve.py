"""Serving launcher: slot-based continuous batching.

LM serving:

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --smoke \
      --requests 8

Graph-analytics serving (--graph; everything routes through
:class:`repro.serve.ServeConfig`):

  PYTHONPATH=src python -m repro.launch.serve --graph --scale 10 \
      --queries 64 --app sssp --cache-dir /tmp/serve-cache
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config
from ..dist.sharding import param_shardings, set_activation_mesh
from ..models.transformer import init_lm
from ..serve import GraphQuery, GraphQueryServer, Request, ServeConfig, Server
from ..train import checkpoint
from .mesh import make_local_mesh


def serve_graph(args):
    """Stand up a GraphQueryServer over a symmetrized RMAT graph and
    push Zipf-skewed repeat-source traffic through it."""
    from ..graph import build_layout, rmat, symmetrize

    g = symmetrize(rmat(args.scale, seed=0, weighted=(args.app == "sssp")))
    layout = build_layout(g, k=args.parts)
    cfg = ServeConfig(max_batch=args.max_batch,
                      cache_size=args.cache_size,
                      cache_backend=args.cache_dir,
                      semantic=not args.no_semantic,
                      warm_threshold=args.warm_threshold)
    srv = GraphQueryServer(layout, cfg)
    rng = np.random.default_rng(0)
    # Zipf-skewed sources: repeat traffic exercises the exact-result
    # entries, near-landmark traffic the seeded path
    pool = rng.integers(0, layout.n, 16)
    for i in range(args.queries):
        src = int(pool[min(rng.zipf(1.5) - 1, len(pool) - 1)])
        srv.submit(GraphQuery(qid=i, app=args.app, params={"source": src}))
    t0 = time.time()
    done = srv.run()
    dt = time.time() - t0
    st = srv.cache.stats()
    print(f"[serve-graph] {len(done)} {args.app} queries in {dt:.2f}s "
          f"({len(done) / dt:.1f} q/s)")
    print(f"[serve-graph] result hits {srv.cache_hits} / misses "
          f"{srv.cache_misses}; semantic hits {srv.semantic_hits} / "
          f"misses {srv.semantic_misses}; backend {st}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", action="store_true",
                    help="serve graph-analytics queries instead of an LM")
    ap.add_argument("--arch")
    ap.add_argument("--app", default="sssp",
                    choices=["bfs", "sssp", "sssp_parents"])
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--parts", type=int, default=16)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--cache-size", type=int, default=128)
    ap.add_argument("--cache-dir", default=None,
                    help="disk-backed cache directory (default: in-memory)")
    ap.add_argument("--no-semantic", action="store_true")
    ap.add_argument("--warm-threshold", type=int, default=3)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    if args.graph:
        return serve_graph(args)
    if not args.arch:
        ap.error("--arch is required unless --graph is given")
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.decoder:
        raise SystemExit(f"{args.arch} is encoder-only; no decode serving")
    params, axes = init_lm(cfg, jax.random.PRNGKey(0))
    dtype = jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16
    if args.ckpt:
        params, _, st = checkpoint.restore(args.ckpt, params, {})
        print(f"[serve] loaded checkpoint step {st}")
    # place params via the sharding-rules layer (FSDP/TP degenerate to
    # replicated on the 1-device smoke mesh) and activate constraints
    mesh = make_local_mesh()
    set_activation_mesh(mesh)
    params = jax.tree_util.tree_map(
        jax.device_put, params, param_shardings(axes, params, mesh))
    srv = Server(params, cfg, n_slots=args.slots, max_len=args.max_len,
                 dtype=dtype)
    rng = np.random.default_rng(0)
    for r in range(args.requests):
        srv.submit(Request(rid=r,
                           prompt=rng.integers(0, cfg.vocab,
                                               rng.integers(4, 16),
                                               dtype=np.int32),
                           max_new=args.max_new))
    t0 = time.time()
    done = srv.run()
    dt = time.time() - t0
    tok = sum(len(d.out) for d in done)
    print(f"[serve] {len(done)} requests, {tok} tokens, {dt:.1f}s "
          f"({tok / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
