import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # every cell
  PYTHONPATH=src python -m repro.launch.dryrun --graph          # PPM engine cells

Results (memory analysis, cost analysis, collective bytes, roofline terms)
are written incrementally to results/dryrun/<cell>.json; existing cells are
skipped unless --force.
"""
import argparse
import json
import time
import traceback

import jax
import numpy as np

from ..configs import SHAPES, all_cells, cell_status, get_config
from ..roofline import collective_bytes, model_flops, roofline_terms
from .mesh import make_production_mesh

RESULTS = os.path.join(os.path.dirname(__file__), "../../..", "results",
                       "dryrun")
RESULTS = os.path.abspath(RESULTS)


def _mesh_tag(multi_pod):
    return "pod2x16x16" if multi_pod else "pod16x16"


VARIANTS = {
    "attn_dp": dict(sharding_overrides=(("heads", None), ("kv", None))),
    "ep": dict(sharding_overrides=(("experts", "model"), ("ff", None)),
               moe_ep=True),
    "attn_dp_ep": dict(sharding_overrides=(("heads", None), ("kv", None),
                                           ("experts", "model"),
                                           ("ff", None)),
                       moe_ep=True),
    "ppm_ep": dict(sharding_overrides=(("experts", "model"), ("ff", None)),
                   moe_impl="ppm_ep"),
    "ssd_q64": dict(ssm_chunk=64),
    "ssd_q64_bf16": dict(ssm_chunk=64, ssm_intra_bf16=True),
    "ssd_bf16": dict(ssm_intra_bf16=True),
    "remat_dots": dict(remat_policy="dots"),
    "zero1": dict(zero1=True),
    "ppm_ep_zero1": dict(sharding_overrides=(("experts", "model"),
                                             ("ff", None)),
                         moe_impl="ppm_ep", zero1=True),
    "ssd_bf16_remat_dots": dict(ssm_intra_bf16=True, remat_policy="dots"),
}


def run_lm_cell(arch: str, shape: str, multi_pod: bool,
                moe_impl: str = "dense_dp", variant: str = None) -> dict:
    import dataclasses
    from ..configs import get_config as _gc
    from .specs import build_cell
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    cfg = None
    zero1 = False
    if variant:
        opts = dict(VARIANTS[variant])
        zero1 = opts.pop("zero1", False)
        if opts:
            cfg = dataclasses.replace(_gc(arch), **opts)
    fn, args, in_sh, out_sh, meta = build_cell(arch, shape, mesh, cfg=cfg,
                                               moe_impl=moe_impl,
                                               zero1=zero1)
    if variant:
        meta["variant"] = variant
    t0 = time.time()
    with jax.default_device(jax.devices("cpu")[0]):
        lowered = jax.jit(fn, in_shardings=in_sh,
                          out_shardings=out_sh).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    return summarize(compiled, meta, mesh, chips, t_lower, t_compile)


def summarize(compiled, meta, mesh, chips, t_lower, t_compile) -> dict:
    from ..dist.compat import cost_analysis
    cost = cost_analysis(compiled)
    try:
        mem = compiled.memory_analysis()
        mem_d = dict(
            argument_bytes=getattr(mem, "argument_size_in_bytes", None),
            output_bytes=getattr(mem, "output_size_in_bytes", None),
            temp_bytes=getattr(mem, "temp_size_in_bytes", None),
            peak_bytes=getattr(
                mem, "serialized_size_in_bytes", None),
        )
    except Exception as e:                                    # noqa: BLE001
        mem_d = {"error": str(e)}
    hlo = compiled.as_text()
    # persist the HLO so roofline terms can be re-derived without recompiling
    import gzip
    tag = f"{meta['arch']}_{meta['shape']}_{_mesh_tag(len(mesh.shape) == 3)}"
    if meta.get("variant"):
        tag += f"_v_{meta['variant']}"
    os.makedirs(os.path.join(RESULTS, "hlo"), exist_ok=True)
    with gzip.open(os.path.join(RESULTS, "hlo", tag + ".hlo.gz"), "wt") as f:
        f.write(hlo)
    # trip-count-aware HLO walk (cost_analysis counts loop bodies once)
    from ..hlo_cost import analyze
    walk = analyze(hlo, default_group=chips)
    flops = float(walk["flops"])
    byts = float(walk["bytes"])
    coll = collective_bytes(hlo, default_group=chips)
    terms = roofline_terms(flops, byts, walk["wire_bytes"])
    cfg = None
    try:
        cfg = get_config(meta["arch"])
    except Exception:                                          # noqa: BLE001
        pass
    mf = (model_flops(cfg, meta["seq"], meta["batch"], meta["kind"])
          if cfg is not None else None)
    out = dict(meta,
               chips=chips, mesh=dict(mesh.shape),
               t_lower_s=round(t_lower, 1), t_compile_s=round(t_compile, 1),
               flops_per_dev=flops, bytes_per_dev=byts,
               wire_bytes_per_dev=walk["wire_bytes"],
               coll_counts=walk["coll_counts"],
               xla_cost_analysis=dict(
                   flops=float(cost.get("flops", 0.0)),
                   bytes=float(cost.get("bytes accessed", 0.0))),
               collectives_flat=coll.as_dict(), memory=mem_d,
               roofline=terms,
               model_flops_total=mf,
               useful_ratio=(mf / (flops * chips)
                             if mf and flops else None),
               hlo_bytes=len(hlo))
    return out


def run_graph_cell(app: str, mode: str, multi_pod: bool,
                   scale: int = 30, edge_factor: int = 16,
                   variant: str = "") -> dict:
    """PPM engine dry-run: one iteration step on a synthetic rmat<scale>."""
    from ..apps.bfs import bfs_program
    from ..apps.pagerank import pagerank_program
    from ..dist.compat import (NamedSharding, PartitionSpec as P,
                               shard_map)
    from ..dist.engine import build_dc_step, build_sc_step
    from ..graph.shard import sharded_spec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    axes = tuple(mesh.axis_names)
    n, m = 1 << scale, (1 << scale) * edge_factor
    arrs, gmeta = sharded_spec(n, m, chips, weighted=False)
    nv, D = gmeta["nv"], gmeta["D"]
    N = D * nv
    f = jax.ShapeDtypeStruct
    if app == "pagerank":
        prog = pagerank_program(n)
        state = {"pr": f((N,), np.float32), "deg": f((N,), np.float32)}
    else:
        prog = bfs_program()
        state = {"parent": f((N,), np.int32), "level": f((N,), np.int32),
                 "vid": f((N,), np.uint32)}
    active = f((N,), np.bool_)
    dense = "dense" in variant
    bf16 = "bf16" in variant
    if mode == "hybrid":
        from ..dist.engine import build_hybrid_step
        body = build_hybrid_step(prog, gmeta, axes)
    elif mode == "dc":
        body = build_dc_step(prog, gmeta, axes, dense_frontier=dense,
                             wire_bf16=bf16)
    else:
        body = build_sc_step(prog, gmeta, axes)

    if mode == "hybrid":
        def step(state, active, arrays, it, dc_mask):
            return shard_map(
                body, mesh=mesh,
                in_specs=(P(axes), P(axes), P(axes), P(), P(axes)),
                out_specs=(P(axes), P(axes)))(state, active, arrays, it,
                                              dc_mask)
    else:
        def step(state, active, arrays, it):
            return shard_map(
                body, mesh=mesh,
                in_specs=(P(axes), P(axes), P(axes), P()),
                out_specs=(P(axes), P(axes)))(state, active, arrays, it)

    sh = NamedSharding(mesh, P(axes))
    rep = NamedSharding(mesh, P())
    in_sh = (jax.tree_util.tree_map(lambda _: sh, state), sh,
             jax.tree_util.tree_map(lambda _: sh, arrs), rep)
    out_sh = (jax.tree_util.tree_map(lambda _: sh, state), sh)
    it = f((), np.int32)
    t0 = time.time()
    if mode == "hybrid":
        dc_mask = f((chips * gmeta["kpd"],), np.bool_)
        lowered = jax.jit(step, in_shardings=in_sh + (sh,),
                          out_shardings=out_sh).lower(state, active, arrs,
                                                      it, dc_mask)
    else:
        lowered = jax.jit(step, in_shardings=in_sh,
                          out_shardings=out_sh).lower(state, active, arrs, it)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    vtag = f"-{variant}" if variant else ""
    meta = dict(arch=f"gpop-{app}-{mode}{vtag}", shape=f"rmat{scale}",
                seq=m, batch=n, kind="graph")
    return summarize(compiled, meta, mesh, chips, t_lower, t_compile)


def cell_path(tag: str) -> str:
    os.makedirs(RESULTS, exist_ok=True)
    return os.path.join(RESULTS, tag + ".json")


def run_and_save(tag, fn, force=False):
    path = cell_path(tag)
    if os.path.exists(path) and not force:
        print(f"[skip-cached] {tag}")
        return json.load(open(path))
    try:
        res = fn()
        with open(path, "w") as f:
            json.dump(res, f, indent=1, default=str)
        r = res["roofline"]
        print(f"[ok] {tag}: compile={res['t_compile_s']}s "
              f"compute={r['compute_s']:.2e}s memory={r['memory_s']:.2e}s "
              f"collective={r['collective_s']:.2e}s dom={r['dominant']}")
        return res
    except Exception as e:                                    # noqa: BLE001
        err = dict(tag=tag, error=str(e),
                   trace=traceback.format_exc()[-2000:])
        with open(cell_path(tag + ".FAILED"), "w") as f:
            json.dump(err, f, indent=1)
        print(f"[FAIL] {tag}: {e}")
        return err


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--graph", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--moe-impl", default="dense_dp")
    ap.add_argument("--variant", default=None,
                    help="LM: attn_dp|ep|attn_dp_ep; graph: dense|bf16|dense_bf16")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    if args.graph:
        v = args.variant or ""
        vtag = f"-{v}" if v else ""
        for app, mode in [("pagerank", "dc"), ("bfs", "sc"), ("bfs", "dc"),
                          ("bfs", "hybrid")]:
            if v and mode != "dc":
                continue
            if v and mode == "hybrid":
                continue
            for mp in meshes:
                tag = f"gpop-{app}-{mode}{vtag}_{_mesh_tag(mp)}"
                run_and_save(tag, lambda a=app, m=mode, p=mp:
                             run_graph_cell(a, m, p, variant=v), args.force)
        return

    if args.all:
        cells = [(a, s) for a, s, st in all_cells() if st == "run"]
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape)]

    for arch, shape in cells:
        st = cell_status(arch, shape)
        if st != "run":
            print(f"[skip] {arch} {shape}: {st}")
            continue
        for mp in meshes:
            tag = f"{arch}_{shape}_{_mesh_tag(mp)}"
            if args.moe_impl != "dense_dp":
                tag += f"_{args.moe_impl}"
            if args.variant:
                tag += f"_v_{args.variant}"
            run_and_save(tag, lambda a=arch, s=shape, p=mp:
                         run_lm_cell(a, s, p, args.moe_impl, args.variant),
                         args.force)


if __name__ == "__main__":
    main()
