"""Assigned-architecture registry + input-shape table.

Shapes (per the brief):
  train_4k     seq 4096,   global batch 256  (train_step)
  prefill_32k  seq 32768,  global batch 32   (serve prefill / encoder fwd)
  decode_32k   seq 32768,  global batch 128  (serve_step, 1 new token)
  long_500k    seq 524288, global batch 1    (long-context serve_step)

Skips (DESIGN.md section "Shape/skip matrix"):
  decode shapes for encoder-only hubert-xlarge;
  long_500k for pure full-attention archs (yi-34b, yi-6b, mistral-nemo-12b,
  qwen2-0.5b, pixtral-12b) - not sub-quadratic.
"""
import importlib

ARCHS = {
    "zamba2-7b": "zamba2_7b",
    "mamba2-780m": "mamba2_780m",
    "yi-34b": "yi_34b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "qwen2-0.5b": "qwen2_0_5b",
    "yi-6b": "yi_6b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "mixtral-8x7b": "mixtral_8x7b",
    "pixtral-12b": "pixtral_12b",
    "hubert-xlarge": "hubert_xlarge",
}

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}


def get_config(arch: str):
    mod = importlib.import_module(f".{ARCHS[arch]}", __package__)
    return mod.config()


def get_smoke_config(arch: str):
    mod = importlib.import_module(f".{ARCHS[arch]}", __package__)
    return mod.smoke_config()


def cell_status(arch: str, shape: str) -> str:
    """'run' or a documented skip reason for the (arch x shape) cell."""
    cfg = get_config(arch)
    kind = SHAPES[shape]["kind"]
    if kind == "decode" and not cfg.decoder:
        return "skip: encoder-only arch has no decode step"
    if shape == "long_500k" and not cfg.sub_quadratic():
        return "skip: full-attention arch is not sub-quadratic at 500k"
    return "run"


def all_cells():
    for a in ARCHS:
        for s in SHAPES:
            yield a, s, cell_status(a, s)
