"""hubert-xlarge [audio]: encoder-only transformer (w2v2 arch); frame
frontend STUBBED with precomputed frame embeddings per brief
[arXiv:2106.07447; unverified].  48L d_model=1280 16H (kv=16, MHA,
d_head=80) d_ff=5120 vocab=504 (masked-prediction codebook).
Encoder-only => no decode shapes (skip noted in DESIGN.md)."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", family="audio", n_layers=48, d_model=1280,
        n_heads=16, n_kv=16, d_head=80, d_ff=5120, vocab=504,
        causal=False, frontend="frame")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hubert-smoke", family="audio", n_layers=3, d_model=64,
        n_heads=4, n_kv=4, d_head=16, d_ff=128, vocab=32, causal=False,
        frontend="frame", dtype="float32")
