"""pixtral-12b [vlm]: pixtral-ViT frontend (STUB: precomputed patch
embeddings per brief) + mistral-nemo-12b text backbone
[hf:mistralai/Pixtral-12B-2409; unverified].  40L d_model=5120 32H (kv=8)
d_ff=14336 vocab=131072."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", family="vlm", n_layers=40, d_model=5120,
        n_heads=32, n_kv=8, d_head=128, d_ff=14336, vocab=131072,
        rope_theta=1_000_000.0, frontend="patch")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-smoke", family="vlm", n_layers=3, d_model=64,
        n_heads=4, n_kv=2, d_head=16, d_ff=128, vocab=256,
        frontend="patch", dtype="float32")
