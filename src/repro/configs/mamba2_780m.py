"""mamba2-780m [ssm]: pure SSD (state-space duality), attention-free
[arXiv:2405.21060; unverified].  48L d_model=1536, d_inner=3072,
headdim=64 (48 ssm heads), d_state=128, vocab=50280."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m", family="ssm", n_layers=48, d_model=1536,
        n_heads=0, n_kv=0, d_head=0, d_ff=0, vocab=50280,
        ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m-smoke", family="ssm", n_layers=3, d_model=64,
        n_heads=0, n_kv=0, d_head=0, d_ff=0, vocab=256,
        ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_chunk=16,
        dtype="float32")
