"""yi-34b [dense]: llama-arch GQA [arXiv:2403.04652; hf].
60L d_model=7168 56H (kv=8, d_head=128) d_ff=20480 vocab=64000."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-34b", family="dense", n_layers=60, d_model=7168,
        n_heads=56, n_kv=8, d_head=128, d_ff=20480, vocab=64000,
        rope_theta=5_000_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="yi-34b-smoke", family="dense", n_layers=3, d_model=64,
        n_heads=8, n_kv=2, d_head=8, d_ff=160, vocab=256, dtype="float32")
