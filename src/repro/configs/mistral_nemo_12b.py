"""mistral-nemo-12b [dense]: 128k-context GQA
[hf:mistralai/Mistral-Nemo-Base-2407; hf].  40L d_model=5120 32H (kv=8,
d_head=128) d_ff=14336 vocab=131072."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b", family="dense", n_layers=40, d_model=5120,
        n_heads=32, n_kv=8, d_head=128, d_ff=14336, vocab=131072,
        rope_theta=1_000_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b-smoke", family="dense", n_layers=3,
        d_model=64, n_heads=4, n_kv=2, d_head=16, d_ff=128, vocab=256,
        dtype="float32")
