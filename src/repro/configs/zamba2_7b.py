"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention block every 6
layers [arXiv:2411.15242; unverified].  81L d_model=3584, GQA 32H kv=32
(MHA, d_head=112), d_ff=14336, vocab=32000, ssm_state=64.
Simplifications (documented, DESIGN.md): one shared block (no per-invocation
LoRA), shared-block input = concat(hidden, initial embedding)."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584,
        n_heads=32, n_kv=32, d_head=112, d_ff=14336, vocab=32000,
        ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128,
        attn_every=6)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b-smoke", family="hybrid", n_layers=4, d_model=64,
        n_heads=4, n_kv=4, d_head=16, d_ff=128, vocab=256,
        ssm_state=8, ssm_expand=2, ssm_head_dim=16, ssm_chunk=16,
        attn_every=2, dtype="float32")
