"""mixtral-8x7b [moe]: 8 experts top-2, sliding-window attention
[arXiv:2401.04088; hf].  32L d_model=4096 32H (kv=8, d_head=128)
expert d_ff=14336 vocab=32000, SWA window 4096."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", family="moe", n_layers=32, d_model=4096,
        n_heads=32, n_kv=8, d_head=128, d_ff=0, vocab=32000,
        moe_experts=8, moe_top_k=2, moe_d_ff=14336, swa_window=4096,
        rope_theta=1_000_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-smoke", family="moe", n_layers=3, d_model=64,
        n_heads=4, n_kv=2, d_head=16, d_ff=0, vocab=256, moe_experts=4,
        moe_top_k=2, moe_d_ff=96, swa_window=16, dtype="float32")
