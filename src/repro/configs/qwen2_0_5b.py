"""qwen2-0.5b [dense]: GQA with QKV bias [arXiv:2407.10671; hf].
24L d_model=896 14H (kv=2, d_head=64) d_ff=4864 vocab=151936."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b", family="dense", n_layers=24, d_model=896,
        n_heads=14, n_kv=2, d_head=64, d_ff=4864, vocab=151936,
        qkv_bias=True, rope_theta=1_000_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b-smoke", family="dense", n_layers=3, d_model=64,
        n_heads=4, n_kv=2, d_head=16, d_ff=128, vocab=256, qkv_bias=True,
        dtype="float32")
