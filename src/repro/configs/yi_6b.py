"""yi-6b [dense]: llama-arch GQA [arXiv:2403.04652; hf].
32L d_model=4096 32H (kv=4, d_head=128) d_ff=11008 vocab=64000."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-6b", family="dense", n_layers=32, d_model=4096,
        n_heads=32, n_kv=4, d_head=128, d_ff=11008, vocab=64000,
        rope_theta=5_000_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="yi-6b-smoke", family="dense", n_layers=3, d_model=64,
        n_heads=4, n_kv=2, d_head=16, d_ff=96, vocab=256, dtype="float32")
