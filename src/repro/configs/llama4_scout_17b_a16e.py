"""llama4-scout-17b-a16e [moe]: 16 routed experts top-1 + shared expert
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].  48L d_model=5120 40H
(kv=8, d_head=128) expert d_ff=8192 vocab=202048.  Early-fusion multimodal
frontend out of scope per brief (text backbone)."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e", family="moe", n_layers=48,
        d_model=5120, n_heads=40, n_kv=8, d_head=128, d_ff=0,
        vocab=202048, moe_experts=16, moe_top_k=1, moe_d_ff=8192,
        moe_shared_expert=True, rope_theta=500_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-smoke", family="moe", n_layers=3, d_model=64,
        n_heads=4, n_kv=2, d_head=16, d_ff=0, vocab=256, moe_experts=4,
        moe_top_k=1, moe_d_ff=96, moe_shared_expert=True, dtype="float32")
