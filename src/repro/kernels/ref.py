"""Pure-jnp oracles for the Pallas kernels.

Each function is the semantic ground truth used by per-kernel allclose tests
(interpret mode) and by the engine's non-Pallas path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _identity(monoid: str, dtype):
    if monoid == "add":
        return jnp.zeros((), dtype)
    if monoid == "min":
        return (jnp.array(jnp.inf, dtype)
                if jnp.issubdtype(dtype, jnp.floating)
                else jnp.array(jnp.iinfo(dtype).max, dtype))
    if monoid == "max":
        return (jnp.array(-jnp.inf, dtype)
                if jnp.issubdtype(dtype, jnp.floating)
                else jnp.array(jnp.iinfo(dtype).min, dtype))
    raise ValueError(monoid)


def segment_combine_ref(vals, valid, ids, num_segments, monoid="add"):
    """Monoid fold of valid messages by destination + touched flags."""
    ident = _identity(monoid, vals.dtype)
    vals = jnp.where(valid.astype(bool), vals, ident)
    if monoid == "add":
        acc = jax.ops.segment_sum(vals, ids, num_segments=num_segments)
    elif monoid == "min":
        acc = jax.ops.segment_min(vals, ids, num_segments=num_segments)
        acc = jnp.where(jnp.isinf(acc) if jnp.issubdtype(vals.dtype, jnp.floating)
                        else acc == ident, ident, acc)
    elif monoid == "max":
        acc = jax.ops.segment_max(vals, ids, num_segments=num_segments)
        acc = jnp.where(jnp.isinf(acc) if jnp.issubdtype(vals.dtype, jnp.floating)
                        else acc == ident, ident, acc)
    else:
        raise ValueError(monoid)
    touched = jax.ops.segment_max(valid.astype(jnp.int32), ids,
                                  num_segments=num_segments) > 0
    return acc, touched


def dc_gather_ref(msg_x, active, png_src, png_valid, monoid="add"):
    """Scatter-phase DC message materialization: values of active sources,
    monoid identity elsewhere (the paper's 'scatter whole partition' with
    array-exact no-op semantics)."""
    ident = _identity(monoid, msg_x.dtype)
    n_pad = msg_x.shape[0]
    src = jnp.minimum(png_src, n_pad - 1)
    ok = png_valid.astype(bool) & active[src]
    return jnp.where(ok, msg_x[src], ident)


def spmv_block_ref(x, msg_slot, png_src, edge_dst, edge_valid, edge_w,
                   n_pad):
    """Fused partition-centric SpMV (PageRank DC inner loop):
    y[dst] += w * x[src] over the static dc_bin layout."""
    nm = png_src.shape[0]
    src = jnp.minimum(png_src, n_pad - 1)
    msg = jnp.where(png_src < n_pad, x[src], 0.0)
    msg_p = jnp.concatenate([msg, jnp.zeros((1,), x.dtype)])
    ev = msg_p[jnp.minimum(msg_slot, nm)]
    if edge_w is not None:
        ev = ev * edge_w
    ev = jnp.where(edge_valid.astype(bool), ev, 0.0)
    return jax.ops.segment_sum(ev, jnp.minimum(edge_dst, n_pad),
                               num_segments=n_pad + 1)[:n_pad]
