"""Blocked segmented monoid fold — the Gather phase over a message stream.

This is the shard_map-side realization of the paper's Gather loop (§3.2,
Alg. 4): a device receives its bin column as one flat message stream
``(vals, valid, ids)`` and folds it into a ``[num_segments]`` accumulator
(``num_segments = nv + 1``: the device's vertices plus one overflow bin
that absorbs sentinel ids).  The paper's claim that this runs lock- and
atomic-free out of cache maps onto the kernel as:

  * the grid walks fixed-size VMEM blocks of the message stream
    (``fold_tile`` messages per step) — the bins are streamed sequentially,
    never random-accessed;
  * the accumulator block (``[1, num_segments_padded]``) stays resident in
    VMEM across *all* grid steps (the output block index is constant), so
    every partial combine is a register/VMEM operation — no scatter-add,
    no ``jax.ops.segment_*``, no atomics anywhere in the lowering;
  * block partials compose through the monoid because TPU grid steps
    execute sequentially over a revisited output block (the same
    accumulation contract :mod:`repro.kernels.segment_combine` relies on).

Because the fold is a plain ``pallas_call`` over per-shard arrays (no
collectives, no layout capture), it traces cleanly inside ``shard_map``
bodies — this is the kernel behind registry entry ``fold``.

All three monoids fold as masked VPU reduces over the one-hot block (an
MXU one-hot matmul would be faster for float adds but turns a single
non-finite message into NaN across every lane via inf*0, and truncates
int32 payloads above 2**24 through the f32 round trip).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .segment_combine import _identity_val

DEFAULT_FOLD_TILE = 256
ENV_FOLD_TILE = "REPRO_FOLD_TILE"
# The one-hot combine materializes a [fold_tile, num_segments_padded]
# block per grid step, so compute and VMEM grow linearly in the segment
# count: 256 x 4096 x 4B = 4 MB keeps the block (plus the resident
# accumulator) inside a TPU core's ~16 MB VMEM.  Above the cap the
# FoldKernel wrapper (repro.kernels.ops) switches to the two-level
# blocked fold (repro.kernels.fold_two_level): per-bucket [q]-sized
# sub-accumulators whose VMEM footprint is bounded regardless of the
# segment count — still Pallas, still no segment/scatter ops.  The cap
# is therefore a *crossover point* between two Pallas lowerings, not a
# handoff to ref.
DEFAULT_FOLD_MAX_SEGMENTS = 4096
ENV_FOLD_MAX_SEGMENTS = "REPRO_FOLD_MAX_SEGMENTS"
_LANES = 128


def default_fold_tile() -> int:
    """Message-tile size for the blocked fold: the ``REPRO_FOLD_TILE``
    override if set, else the static default (autotune sweeps pass an
    explicit ``fold_tile`` instead)."""
    env = os.environ.get(ENV_FOLD_TILE)
    return int(env) if env else DEFAULT_FOLD_TILE


def max_fold_segments() -> int:
    """Largest segment count the *flat* blocked kernel will take on before
    the FoldKernel wrapper switches to the two-level blocked fold
    (``REPRO_FOLD_MAX_SEGMENTS`` overrides the static default)."""
    env = os.environ.get(ENV_FOLD_MAX_SEGMENTS)
    return int(env) if env else DEFAULT_FOLD_MAX_SEGMENTS


def _kernel(vals_ref, valid_ref, ids_ref,              # VMEM in (one block)
            acc_ref, touched_ref,                      # VMEM out (resident)
            *, monoid: str, nsp: int):
    t = pl.program_id(0)
    ident = _identity_val(monoid, acc_ref.dtype)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, ident)
        touched_ref[...] = jnp.zeros_like(touched_ref)

    vals = vals_ref[...]                                # [T]
    valid = valid_ref[...] > 0                          # [T]
    ids = ids_ref[...]                                  # [T]
    cols = jax.lax.broadcasted_iota(jnp.int32, (vals.shape[0], nsp), 1)
    onehot = (ids[:, None] == cols) & valid[:, None]    # [T, nsp]
    if monoid == "add":
        # masked VPU sum, NOT a one-hot MXU matmul: inf*0 = NaN in a
        # matmul would pollute every other segment's lane the moment one
        # message diverges, where the ref fold confines it to its segment
        masked = jnp.where(onehot, vals[:, None],
                           jnp.zeros((), acc_ref.dtype))
        contrib = jnp.sum(masked, axis=0)
        acc_ref[...] = acc_ref[...] + contrib.astype(acc_ref.dtype)[None, :]
    elif monoid == "min":
        masked = jnp.where(onehot, vals[:, None], ident)
        acc_ref[...] = jnp.minimum(acc_ref[...],
                                   jnp.min(masked, axis=0)[None, :])
    elif monoid == "max":
        masked = jnp.where(onehot, vals[:, None], ident)
        acc_ref[...] = jnp.maximum(acc_ref[...],
                                   jnp.max(masked, axis=0)[None, :])
    touched_ref[...] = jnp.maximum(
        touched_ref[...],
        jnp.max(onehot.astype(jnp.int32), axis=0)[None, :])


@functools.partial(jax.jit, static_argnames=("num_segments", "monoid",
                                             "fold_tile", "interpret"))
def blocked_segment_fold(vals, valid, ids, num_segments: int, *,
                         monoid: str = "add", fold_tile: int = 256,
                         interpret: bool = True):
    """Segmented monoid fold of a message stream, blocked through VMEM.

    Args:
      vals:  [N] message value per slot.
      valid: [N] bool/int validity; invalid slots contribute nothing.
      ids:   [N] int32 segment id per slot.  Ids outside
             ``[0, num_segments)`` contribute nothing (the engines point
             sentinel slots at the overflow bin ``num_segments - 1``).
      num_segments: static segment count (engines pass ``nv + 1``).
      fold_tile: messages per grid step (the VMEM block size).
    Returns:
      acc [num_segments] monoid fold, touched [num_segments] bool.
    """
    n = vals.shape[0]
    nt = max(1, -(-n // fold_tile))
    n_pad = nt * fold_tile
    nsp = -(-num_segments // _LANES) * _LANES
    ident = _identity_val(monoid, vals.dtype)
    vals = jnp.pad(vals, (0, n_pad - n), constant_values=ident)
    valid = jnp.pad(valid.astype(jnp.int32), (0, n_pad - n))
    ids = jnp.pad(ids.astype(jnp.int32), (0, n_pad - n))
    acc, touched = pl.pallas_call(
        functools.partial(_kernel, monoid=monoid, nsp=nsp),
        grid=(nt,),
        in_specs=[pl.BlockSpec((fold_tile,), lambda t: (t,)),
                  pl.BlockSpec((fold_tile,), lambda t: (t,)),
                  pl.BlockSpec((fold_tile,), lambda t: (t,))],
        out_specs=[pl.BlockSpec((1, nsp), lambda t: (0, 0)),
                   pl.BlockSpec((1, nsp), lambda t: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, nsp), vals.dtype),
                   jax.ShapeDtypeStruct((1, nsp), jnp.int32)],
        interpret=interpret,
    )(vals, valid, ids)
    return acc[0, :num_segments], touched[0, :num_segments] > 0
