"""Fused partition-centric SpMV — the PageRank DC-mode inner loop.

This is the flagship kernel of the reproduction: one pass over the gather-
order (dst-major) dc_bin layout computes ``y[dst] += w * x[src]`` with BOTH
partition tiles VMEM-resident:

  * ``x`` tile of the *source* partition (block = tile_src_part[t]),
  * ``y`` accumulator tile of the *destination* partition
    (block = tile_dst_part[t], revisited consecutively in dst-major order).

On a CPU this is exactly the paper's cache-blocked PCPM loop ([17]); on TPU
the two q-vectors sit in VMEM and the edge stream is the only HBM traffic —
the layout's arithmetic-intensity shaping is the paper's contribution, the
MXU one-hot matmul is the TPU-native fold.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(tile_dst_ref, tile_src_ref, tile_first_ref,   # scalar prefetch
            x_ref, srcl_ref, dstl_ref, valid_ref, w_ref,  # VMEM in
            y_ref, *, q: int, weighted: bool):
    t = pl.program_id(0)

    @pl.when(tile_first_ref[t] > 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    x = x_ref[0, :]                                        # [q]
    vals = x[srcl_ref[...]]                                # [T]
    if weighted:
        vals = vals * w_ref[...]
    vals = jnp.where(valid_ref[...] > 0, vals, 0.0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (vals.shape[0], q), 1)
    onehot = (dstl_ref[...][:, None] == cols).astype(jnp.float32)
    contrib = jnp.dot(vals.astype(jnp.float32)[None, :], onehot,
                      preferred_element_type=jnp.float32)
    y_ref[...] = y_ref[...] + contrib.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("k", "q", "edge_tile",
                                             "weighted", "interpret"))
def spmv_block(x, edge_src_local, edge_dst_local, edge_valid, edge_w,
               tile_dst_part, tile_src_part, tile_first,
               *, k: int, q: int, edge_tile: int, weighted: bool = False,
               interpret: bool = True):
    """One partition-centric SpMV pass.  Returns y[k, q] = A^T x (+weights)."""
    nt = tile_dst_part.shape[0]
    if edge_w is None:
        edge_w = jnp.ones_like(x, shape=(edge_src_local.shape[0],))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1, q), lambda t, td, ts, tf: (ts[t], 0)),
            pl.BlockSpec((edge_tile,), lambda t, *pf: (t,)),
            pl.BlockSpec((edge_tile,), lambda t, *pf: (t,)),
            pl.BlockSpec((edge_tile,), lambda t, *pf: (t,)),
            pl.BlockSpec((edge_tile,), lambda t, *pf: (t,)),
        ],
        out_specs=pl.BlockSpec((1, q), lambda t, td, ts, tf: (td[t], 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, q=q, weighted=weighted),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((k, q), x.dtype),
        interpret=interpret,
    )(tile_dst_part, tile_src_part, tile_first.astype(jnp.int32),
      x, edge_src_local, edge_dst_local, edge_valid.astype(jnp.int32),
      edge_w)
