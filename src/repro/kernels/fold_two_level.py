"""Two-level blocked segmented fold — the Gather phase past the VMEM cap.

The flat blocked fold (:mod:`repro.kernels.fold_block`) materializes a
``[fold_tile, num_segments_padded]`` one-hot block per grid step, so its
VMEM footprint grows linearly in the segment count and it stops being
lowerable past ``REPRO_FOLD_MAX_SEGMENTS``.  This kernel lifts that cap by
hierarchical accumulation ("Making Caches Work for Graph Analytics",
Zhang et al.): segment ids are split two-level into a *coarse bucket*
``id // q`` and an *offset within the bucket* ``id % q``, and the fold
runs over a ``(num_buckets, num_tiles)`` grid —

  * the inner grid dimension streams ``fold_tile``-sized message blocks,
    exactly like the flat fold;
  * the outer dimension walks the ``nb = ceil(num_segments / q)`` coarse
    buckets; bucket ``b``'s ``[q]``-sized sub-accumulator is the revisited
    output block, VMEM-resident across the whole inner sweep;
  * the one-hot combine is ``[fold_tile, q]`` — sized by the *bucket*
    width, not the segment count, so VMEM stays bounded for any
    ``num_segments``;
  * a per-tile bucket range ``[bmin, bmax]`` (computed from the valid ids
    before the ``pallas_call``) predicates each grid step: a tile whose
    messages cannot land in bucket ``b`` is skipped.  The engines' DC
    streams are destination-major *sorted* (the pre-written ``dc_bin``
    reads bin columns in order), so each tile covers O(1) buckets and the
    effective work collapses from ``nb x nt`` to ``~nb + nt`` body runs —
    the paper's cache- and work-efficiency, transposed to buckets.

Stage 2 — combining the per-bucket partials into the flat
``[num_segments]`` output — is where the hierarchy pays off: buckets tile
the segment space disjointly, so the combine is a relayout of the
``[nb, q]`` partials, not another reduction pass.  No
``jax.ops.segment_*``, no scatter anywhere in the lowering, so the kernel
traces inside ``shard_map`` bodies just like the flat fold (same registry
contract, same monoids, same masked-VPU combine — the MXU one-hot matmul
stays off the table for the NaN/int-truncation reasons documented in
:mod:`repro.kernels.fold_block`).

``q`` need not divide the segment count, be a power of two, or be
lane-aligned (TPU-native callers should keep it a multiple of 128); the
bucket split uses real division, not a shift.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .segment_combine import _identity_val

# Bucket width of the two-level fold: how many consecutive segments one
# VMEM-resident sub-accumulator covers.  256 keeps the [fold_tile, q]
# one-hot block at flat-fold-default size (256 x 256 x 4B = 256 KB) while
# staying a lane multiple for the TPU path; the autotuner sweeps it
# jointly with fold_tile (Eq. 1's cost model predicts the interaction).
DEFAULT_FOLD_Q = 256
ENV_FOLD_Q = "REPRO_FOLD_Q"


def default_fold_q() -> int:
    """Bucket width for the two-level fold: the ``REPRO_FOLD_Q`` override
    if set, else the static default (autotune sweeps / layouts pass an
    explicit ``fold_q`` instead)."""
    env = os.environ.get(ENV_FOLD_Q)
    return int(env) if env else DEFAULT_FOLD_Q


def _kernel(vals_ref, valid_ref, ids_ref,              # VMEM in (one tile)
            bmin_ref, bmax_ref,                        # VMEM in (per tile)
            acc_ref, touched_ref,                      # VMEM out (resident)
            *, monoid: str, q: int):
    b = pl.program_id(0)
    t = pl.program_id(1)
    ident = _identity_val(monoid, acc_ref.dtype)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, ident)
        touched_ref[...] = jnp.zeros_like(touched_ref)

    # bucket-range predication: tiles with no message in bucket b are
    # skipped — for the engines' destination-sorted streams this is the
    # 2-level active list of the paper, applied to coarse buckets
    @pl.when((bmin_ref[0] <= b) & (b <= bmax_ref[0]))
    def _body():
        vals = vals_ref[...]                            # [T]
        valid = valid_ref[...] > 0                      # [T]
        ids = ids_ref[...]                              # [T]
        bucket = ids // q
        off = ids - bucket * q
        cols = jax.lax.broadcasted_iota(jnp.int32, (vals.shape[0], q), 1)
        onehot = ((off[:, None] == cols) & (bucket == b)[:, None]
                  & valid[:, None])                     # [T, q]
        if monoid == "add":
            masked = jnp.where(onehot, vals[:, None],
                               jnp.zeros((), acc_ref.dtype))
            contrib = jnp.sum(masked, axis=0)
            acc_ref[...] = acc_ref[...] \
                + contrib.astype(acc_ref.dtype)[None, :]
        elif monoid == "min":
            masked = jnp.where(onehot, vals[:, None], ident)
            acc_ref[...] = jnp.minimum(acc_ref[...],
                                       jnp.min(masked, axis=0)[None, :])
        elif monoid == "max":
            masked = jnp.where(onehot, vals[:, None], ident)
            acc_ref[...] = jnp.maximum(acc_ref[...],
                                       jnp.max(masked, axis=0)[None, :])
        touched_ref[...] = jnp.maximum(
            touched_ref[...],
            jnp.max(onehot.astype(jnp.int32), axis=0)[None, :])


@functools.partial(jax.jit, static_argnames=("num_segments", "monoid",
                                             "fold_tile", "fold_q",
                                             "interpret"))
def two_level_segment_fold(vals, valid, ids, num_segments: int, *,
                           monoid: str = "add", fold_tile: int = 256,
                           fold_q: int = DEFAULT_FOLD_Q,
                           interpret: bool = True):
    """Segmented monoid fold via per-bucket VMEM sub-accumulators.

    Same contract as :func:`repro.kernels.fold_block.blocked_segment_fold`
    (and registry kernel ``fold``):

      vals:  [N] message value per slot.
      valid: [N] bool/int validity; invalid slots contribute nothing.
      ids:   [N] int32 segment id per slot; ids outside
             ``[0, num_segments)`` contribute nothing (the engines point
             sentinel slots at the overflow bin ``num_segments - 1``).
      num_segments: static segment count (engines pass ``nv + 1``) — any
             size; VMEM use is bounded by ``fold_tile x fold_q``.
      fold_tile: messages per grid step.
      fold_q: segments per coarse bucket (the sub-accumulator width).
    Returns:
      acc [num_segments] monoid fold, touched [num_segments] bool.
    """
    n = vals.shape[0]
    q = int(fold_q)
    nt = max(1, -(-n // fold_tile))
    n_pad = nt * fold_tile
    nb = max(1, -(-num_segments // q))
    ident = _identity_val(monoid, vals.dtype)
    vals = jnp.pad(vals, (0, n_pad - n), constant_values=ident)
    valid = jnp.pad(valid.astype(jnp.int32), (0, n_pad - n))
    ids = jnp.pad(ids.astype(jnp.int32), (0, n_pad - n))

    # per-tile coarse-bucket ranges over the *valid* slots only: an
    # all-invalid tile gets the empty range [nb, -1] and is never entered
    vb = valid > 0
    bt = jnp.where(vb, ids // q, -1)
    bmax = jnp.clip(jnp.max(bt.reshape(nt, fold_tile), axis=1), -1, nb - 1)
    bmin = jnp.clip(
        jnp.min(jnp.where(vb, ids // q, nb).reshape(nt, fold_tile), axis=1),
        0, nb)

    acc, touched = pl.pallas_call(
        functools.partial(_kernel, monoid=monoid, q=q),
        grid=(nb, nt),
        in_specs=[pl.BlockSpec((fold_tile,), lambda b, t: (t,)),
                  pl.BlockSpec((fold_tile,), lambda b, t: (t,)),
                  pl.BlockSpec((fold_tile,), lambda b, t: (t,)),
                  pl.BlockSpec((1,), lambda b, t: (t,)),
                  pl.BlockSpec((1,), lambda b, t: (t,))],
        out_specs=[pl.BlockSpec((1, q), lambda b, t: (b, 0)),
                   pl.BlockSpec((1, q), lambda b, t: (b, 0))],
        out_shape=[jax.ShapeDtypeStruct((nb, q), vals.dtype),
                   jax.ShapeDtypeStruct((nb, q), jnp.int32)],
        interpret=interpret,
    )(vals, valid, ids, bmin.astype(jnp.int32), bmax.astype(jnp.int32))
    # stage 2: buckets tile the segment space disjointly, so combining the
    # per-bucket partials into the flat output is a relayout, not a fold
    return (acc.reshape(-1)[:num_segments],
            touched.reshape(-1)[:num_segments] > 0)
