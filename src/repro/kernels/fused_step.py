"""Fused scatter→fold DC step — the Gather phase without the message stream.

The composed DC lowering materializes every message twice: the Scatter
kernel writes the full ``[NM]`` bin buffer (values only, the paper's
pre-written ``dc_bin``), the slot gather re-reads it into a ``[NE]``
edge-value stream, and only then does the segmented fold collapse it into
the per-partition accumulators.  Both intermediates round-trip HBM — the
single largest remaining memory-traffic cost of the reproduction, and
exactly the locality argument of the source paper (and of "Making Caches
Work for Graph Analytics": partition-private accumulators should absorb
messages while they are still hot).

This kernel fuses the whole chain: per edge tile it *gathers* the source
value straight out of the vertex-message table, applies the optional edge
function (``apply_weight``), and folds the result directly into the
``[fold_q]`` VMEM-resident sub-accumulators of the two-level layout
(:mod:`repro.kernels.fold_two_level`).  No intermediate message stream
ever hits HBM; Pallas' automatic input-block pipelining double-buffers
the edge-tile fetches against the combine.

Structure (the two-level fold, with the gather pulled inside):

  * grid ``(nb, nt)``: ``nb = ceil(num_segments / fold_q)`` coarse
    destination buckets × ``nt = ceil(NE / edge_tile)`` edge tiles;
  * the message table (``[n_pad + 1]`` vertex values + identity sentinel)
    rides along as a constant-index-map input block, resident across the
    whole grid;
  * bucket ``b``'s ``[1, fold_q]`` sub-accumulator is the revisited
    output block (initialized at ``t == 0``, accumulated across the
    inner sweep);
  * per-tile bucket ranges ``[bmin, bmax]`` — computed from the
    *structurally valid* destinations before the ``pallas_call`` —
    predicate each grid step, so the destination-sorted dc_bin streams
    do ~``nb + nt`` body runs, not ``nb × nt``;
  * the combine is the same masked one-hot VPU reduction as the fold
    kernels (the MXU one-hot matmul stays off the table for the
    NaN/int-truncation reasons documented in
    :mod:`repro.kernels.fold_block`).

Validity is resolved *inside* the kernel: an edge contributes iff its
static slot is real (``edge_valid``) AND its source vertex is live in the
table (``table_valid`` — the engines pass ``active & dc_mask`` there), so
the host never materializes a per-edge validity stream either.

The in-kernel table gather (``table[idx]``) is an arbitrary dynamic
vector gather.  Interpret mode executes it as a plain jnp gather on any
host; Mosaic support for arbitrary VMEM gathers is generation-dependent,
so the ``pallas-native`` registration shares the usual caveat of this
repo's TPU path (untested here — the TPU CI lane is still an open
ROADMAP item).

Env: ``REPRO_FUSED=0`` opts the engines out of fused selection entirely
(they silently fall back to the composed scatter→fold path, which also
remains the path for SC/hybrid streams and unsupported backends).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fold_block import default_fold_tile
from .fold_two_level import default_fold_q
from .segment_combine import _identity_val

ENV_FUSED = "REPRO_FUSED"


def fused_enabled() -> bool:
    """Engine-side opt-out: ``REPRO_FUSED=0`` disables fused DC selection
    (the composed scatter→fold path runs instead).  Default: enabled."""
    return os.environ.get(ENV_FUSED, "1") != "0"


def _kernel(table_ref, tvalid_ref,                     # resident table in
            idx_ref, evalid_ref, dst_ref, w_ref,       # VMEM in (one tile)
            bmin_ref, bmax_ref,                        # VMEM in (per tile)
            acc_ref, touched_ref,                      # VMEM out (resident)
            *, monoid: str, q: int, apply_weight):
    b = pl.program_id(0)
    t = pl.program_id(1)
    ident = _identity_val(monoid, acc_ref.dtype)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, ident)
        touched_ref[...] = jnp.zeros_like(touched_ref)

    # bucket-range predication over the structurally valid destinations:
    # tiles that cannot land a message in bucket b are skipped entirely
    @pl.when((bmin_ref[0] <= b) & (b <= bmax_ref[0]))
    def _body():
        idx = idx_ref[...]                              # [T]
        # the fused gather: source values pulled straight from the
        # resident message table — no [NE] edge-value stream in HBM
        vals = table_ref[...][idx]                      # [T]
        valid = ((tvalid_ref[...][idx] > 0)
                 & (evalid_ref[...] > 0))               # [T]
        if apply_weight is not None:
            vals = apply_weight(vals, w_ref[...]).astype(acc_ref.dtype)
        ids = dst_ref[...]
        bucket = ids // q
        off = ids - bucket * q
        cols = jax.lax.broadcasted_iota(jnp.int32, (vals.shape[0], q), 1)
        onehot = ((off[:, None] == cols) & (bucket == b)[:, None]
                  & valid[:, None])                     # [T, q]
        if monoid == "add":
            masked = jnp.where(onehot, vals[:, None],
                               jnp.zeros((), acc_ref.dtype))
            contrib = jnp.sum(masked, axis=0)
            acc_ref[...] = acc_ref[...] \
                + contrib.astype(acc_ref.dtype)[None, :]
        elif monoid == "min":
            masked = jnp.where(onehot, vals[:, None], ident)
            acc_ref[...] = jnp.minimum(acc_ref[...],
                                       jnp.min(masked, axis=0)[None, :])
        elif monoid == "max":
            masked = jnp.where(onehot, vals[:, None], ident)
            acc_ref[...] = jnp.maximum(acc_ref[...],
                                       jnp.max(masked, axis=0)[None, :])
        touched_ref[...] = jnp.maximum(
            touched_ref[...],
            jnp.max(onehot.astype(jnp.int32), axis=0)[None, :])


@functools.partial(jax.jit, static_argnames=("num_segments", "monoid",
                                             "edge_tile", "fold_q",
                                             "interpret", "apply_weight"))
def fused_scatter_fold(table, table_valid, idx, edge_valid, dst,
                       num_segments: int, *, monoid: str = "add",
                       edge_tile: int = 256,
                       fold_q: int = None,
                       interpret: bool = True,
                       apply_weight=None, w=None):
    """Gather-from-table + edge function + two-level segmented fold, fused.

    Contract (registry kernel ``fused_dc``):

      table:       [M] source value per table slot (the engines pass the
                   vertex message array + identity sentinel).
      table_valid: [M] bool/int; a slot's messages contribute nothing
                   when its source is invalid (inactive / non-DC).
      idx:         [NE] int32 table slot per edge (clamped into range;
                   out-of-range only ever occurs on invalid pad edges).
      edge_valid:  [NE] bool/int static structural validity per edge.
      dst:         [NE] int32 destination segment per edge; ids outside
                   ``[0, num_segments)`` contribute nothing.
      num_segments: static segment count (engines pass ``n_pad + 1`` /
                   ``nv + 1``; the overflow bin is the last segment).
      apply_weight: optional static edge function ``f(vals, w)`` applied
                   to the gathered values (the composed path applies it
                   to the same inputs elementwise, so parity is exact).
      w:           [NE] edge weights; required iff apply_weight is set.
    Returns:
      acc [num_segments] monoid fold, touched [num_segments] bool —
      an edge contributes iff ``table_valid[idx] & edge_valid``.
    """
    ns = int(num_segments)
    q = int(fold_q) if fold_q else default_fold_q()
    tile = int(edge_tile) if edge_tile else default_fold_tile()
    ne = idx.shape[0]
    nt = max(1, -(-ne // tile))
    ne_pad = nt * tile
    nb = max(1, -(-ns // q))
    ident = _identity_val(monoid, table.dtype)

    idx = jnp.clip(idx.astype(jnp.int32), 0, table.shape[0] - 1)
    idx = jnp.pad(idx, (0, ne_pad - ne))
    evalid = jnp.pad(edge_valid.astype(jnp.int32), (0, ne_pad - ne))
    dst = jnp.pad(dst.astype(jnp.int32), (0, ne_pad - ne))
    if apply_weight is not None:
        w = jnp.pad(w, (0, ne_pad - ne))
    else:
        # dummy lane so the in_specs are static; never read by the body
        w = jnp.zeros((ne_pad,), table.dtype)

    # per-tile coarse-bucket ranges over the structurally valid edges: a
    # conservative superset (the table-validity side is resolved in the
    # kernel), exact for the frontier-independent dc_bin structure — an
    # all-invalid tile gets the empty range [nb, -1] and is never entered
    vb = evalid > 0
    bt = jnp.where(vb, dst // q, -1)
    bmax = jnp.clip(jnp.max(bt.reshape(nt, tile), axis=1), -1, nb - 1)
    bmin = jnp.clip(
        jnp.min(jnp.where(vb, dst // q, nb).reshape(nt, tile), axis=1),
        0, nb)

    m = table.shape[0]
    acc, touched = pl.pallas_call(
        functools.partial(_kernel, monoid=monoid, q=q,
                          apply_weight=apply_weight),
        grid=(nb, nt),
        in_specs=[pl.BlockSpec((m,), lambda b, t: (0,)),
                  pl.BlockSpec((m,), lambda b, t: (0,)),
                  pl.BlockSpec((tile,), lambda b, t: (t,)),
                  pl.BlockSpec((tile,), lambda b, t: (t,)),
                  pl.BlockSpec((tile,), lambda b, t: (t,)),
                  pl.BlockSpec((tile,), lambda b, t: (t,)),
                  pl.BlockSpec((1,), lambda b, t: (t,)),
                  pl.BlockSpec((1,), lambda b, t: (t,))],
        out_specs=[pl.BlockSpec((1, q), lambda b, t: (b, 0)),
                   pl.BlockSpec((1, q), lambda b, t: (b, 0))],
        out_shape=[jax.ShapeDtypeStruct((nb, q), table.dtype),
                   jax.ShapeDtypeStruct((nb, q), jnp.int32)],
        interpret=interpret,
    )(table, table_valid.astype(jnp.int32), idx, evalid, dst, w,
      bmin.astype(jnp.int32), bmax.astype(jnp.int32))
    # buckets tile the segment space disjointly: stage 2 is a relayout
    return (acc.reshape(-1)[:ns], touched.reshape(-1)[:ns] > 0)


def ref_fused_scatter_fold(mono, table, table_valid, idx, edge_valid, dst,
                           num_segments: int, apply_weight=None, w=None):
    """Pure-jnp oracle with :func:`fused_scatter_fold`'s exact contract —
    what the ``ref`` backend registers for kernel ``fused_dc`` (and what
    the differential harness checks the Pallas lowering against)."""
    ns = int(num_segments)
    idx = jnp.clip(idx.astype(jnp.int32), 0, table.shape[0] - 1)
    vals = table[idx].astype(mono.dtype)
    valid = table_valid.astype(bool)[idx] & edge_valid.astype(bool)
    if apply_weight is not None:
        vals = apply_weight(vals, w).astype(mono.dtype)
    vals = jnp.where(valid, vals, mono.identity)
    ids = jnp.where(valid, dst.astype(jnp.int32), ns - 1)
    acc = mono.segment_fold(vals, ids, ns)
    touched = jax.ops.segment_max(valid.astype(jnp.int32), ids,
                                  num_segments=ns) > 0
    return acc, touched
