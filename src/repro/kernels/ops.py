"""Layout-bound jit wrappers around the PPM kernels.

``GatherKernel`` / ``ScatterKernel`` / ``SpmvKernel`` bind a
:class:`repro.graph.layout.Layout` once (moving the static bin-grid geometry
to device) and expose the engine-facing API over the Pallas bodies
(``interpret=True`` runs them on CPU for validation; ``interpret=False``
compiles to Mosaic on TPU).  ``RefGather`` / ``RefScatter`` / ``RefSpmv``
are the pure-jnp implementations of the *same* engine-facing API, built on
:mod:`repro.kernels.ref` — the semantic oracle and the fast CPU path.

Engines do not pick between them directly: construct kernels through
:func:`repro.backend.registry.make_kernels` (or the :func:`make_kernels`
convenience re-export below), which resolves the backend from the platform,
the ``REPRO_KERNEL_BACKEND`` override, and per-combination support.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import tracing as obs_tracing
from . import ref as kref
from .dc_gather import dc_gather
from .fold_block import (blocked_segment_fold, default_fold_tile,
                         max_fold_segments)
from .fold_two_level import default_fold_q, two_level_segment_fold
from .fused_step import (fused_enabled, fused_scatter_fold,
                         ref_fused_scatter_fold)
from .segment_combine import segment_combine, _identity_val
from .spmv_block import spmv_block


class GatherKernel:
    """Gather-phase fold bound to a layout (acc + touched over [n_pad])."""

    def __init__(self, layout, monoid_name: str, dtype,
                 interpret: bool = True):
        self.L = layout
        self.monoid = monoid_name
        self.dtype = jnp.dtype(dtype)
        self.interpret = interpret
        self.tile_dst_part = jnp.asarray(layout.tile_dst_part)
        self.tile_src_part = jnp.asarray(layout.tile_src_part)
        self.tile_first = jnp.asarray(layout.tile_first.astype(np.int32))
        self.edge_dst_local = jnp.asarray(layout.edge_dst_local)
        self.has_tiles = jnp.asarray(
            layout.part_has_tiles.astype(np.int32))[:, None]
        self.ident = _identity_val(monoid_name, self.dtype)

    def __call__(self, edge_vals, edge_valid, part_active):
        L = self.L
        with obs_tracing.kernel_scope(
                getattr(self, "_obs_scope", "ppm.gather")):
            acc, touched = segment_combine(
                edge_vals, edge_valid, self.edge_dst_local,
                self.tile_dst_part, self.tile_src_part, self.tile_first,
                part_active, k=L.k, q=L.q, edge_tile=L.edge_tile,
                monoid=self.monoid, interpret=self.interpret)
            # destination partitions with no incoming tiles were never
            # visited
            acc = jnp.where(self.has_tiles > 0, acc, self.ident)
            touched = jnp.where(self.has_tiles > 0, touched, 0)
            return acc.reshape(-1), touched.reshape(-1) > 0


class ScatterKernel:
    """DC scatter-phase message materialization bound to a layout."""

    def __init__(self, layout, monoid_name: str, dtype,
                 interpret: bool = True):
        self.L = layout
        self.monoid = monoid_name
        self.dtype = jnp.dtype(dtype)
        self.interpret = interpret
        self.png_src_local = jnp.asarray(layout.png_src_local)
        self.png_valid = jnp.asarray(
            (layout.png_src < layout.n_pad).astype(np.int32))
        self.png_tile_part = jnp.asarray(layout.png_tile_part)

    def __call__(self, x_flat, active_flat):
        L = self.L
        with obs_tracing.kernel_scope(
                getattr(self, "_obs_scope", "ppm.scatter")):
            return dc_gather(
                x_flat.reshape(L.k, L.q),
                active_flat.astype(jnp.int32).reshape(L.k, L.q),
                self.png_src_local, self.png_valid, self.png_tile_part,
                k=L.k, q=L.q, msg_tile=L.msg_tile, monoid=self.monoid,
                interpret=self.interpret)


class SpmvKernel:
    """Fused partition-centric SpMV bound to a layout (PageRank DC loop)."""

    def __init__(self, layout, interpret: bool = True, weighted=None):
        self.L = layout
        self.interpret = interpret
        self.weighted = layout.weighted if weighted is None else weighted
        self.edge_src_local = jnp.asarray(layout.edge_src_local)
        self.edge_dst_local = jnp.asarray(layout.edge_dst_local)
        self.edge_valid = jnp.asarray(layout.edge_valid.astype(np.int32))
        self.edge_w = (jnp.asarray(layout.edge_w)
                       if (self.weighted and layout.edge_w is not None)
                       else None)
        self.tile_dst_part = jnp.asarray(layout.tile_dst_part)
        self.tile_src_part = jnp.asarray(layout.tile_src_part)
        self.tile_first = jnp.asarray(layout.tile_first.astype(np.int32))
        self.has_tiles = jnp.asarray(
            layout.part_has_tiles.astype(np.int32))[:, None]

    def __call__(self, x_flat):
        L = self.L
        with obs_tracing.kernel_scope(
                getattr(self, "_obs_scope", "ppm.spmv")):
            y = spmv_block(
                x_flat.reshape(L.k, L.q), self.edge_src_local,
                self.edge_dst_local, self.edge_valid, self.edge_w,
                self.tile_dst_part, self.tile_src_part, self.tile_first,
                k=L.k, q=L.q, edge_tile=L.edge_tile,
                weighted=self.edge_w is not None, interpret=self.interpret)
            return jnp.where(self.has_tiles > 0, y, 0.0).reshape(-1)


class FoldKernel:
    """Blocked Pallas segmented fold with the registry's ``fold`` contract.

    Layout-free (the segment count arrives per call): the distributed
    engine folds each device's received bin column under ``shard_map``,
    and the single-device engine folds the compacted SC stream.  The
    message-tile size comes from the tuning sweep (``tile=``), the
    ``REPRO_FOLD_TILE`` override, or the static default, in that order;
    the two-level bucket width resolves the same way (``q=`` /
    ``REPRO_FOLD_Q`` / static default).

    Segment-count regimes — both are Pallas lowerings:

      * ``num_segments <= REPRO_FOLD_MAX_SEGMENTS``: the flat blocked
        fold (one VMEM-resident ``[num_segments_padded]`` accumulator,
        :mod:`repro.kernels.fold_block`);
      * above the cap: the two-level blocked fold (per-bucket ``[q]``
        sub-accumulators, :mod:`repro.kernels.fold_two_level`), whose
        VMEM footprint is bounded by ``fold_tile x q`` for any segment
        count.

    The ref fold no longer rides along as a silent large-``num_segments``
    cliff; ``RefFold`` is what the explicit ``ref`` backend constructs.
    """

    def __init__(self, monoid_name: str, dtype, interpret: bool = True,
                 tile=None, q=None):
        self.monoid = monoid_name
        self.dtype = jnp.dtype(dtype)
        self.interpret = interpret
        self.tile = tile
        self.q = q

    def __call__(self, vals, valid, ids, num_segments):
        ns = int(num_segments)
        tile = int(self.tile) if self.tile else default_fold_tile()
        with obs_tracing.kernel_scope(
                getattr(self, "_obs_scope", "ppm.fold")):
            if ns > max_fold_segments():
                # the flat one-hot block would outgrow VMEM: fold through
                # the per-bucket sub-accumulators instead (still Pallas,
                # still no segment/scatter ops in the lowering)
                q = int(self.q) if self.q else default_fold_q()
                return two_level_segment_fold(
                    vals, valid, ids, ns, monoid=self.monoid,
                    fold_tile=tile, fold_q=q, interpret=self.interpret)
            return blocked_segment_fold(
                vals, valid, ids, ns, monoid=self.monoid,
                fold_tile=tile, interpret=self.interpret)


class RefFold:
    """Pure-jnp segmented fold with FoldKernel's exact call contract.

    Tightened over a bare ``Monoid.segment_fold``: invalid slots are
    masked to the identity *inside* the fold (callers need not pre-mask)
    and ``touched`` reports exactly the segments a valid message reached —
    the same semantics the blocked kernel realizes with its one-hot mask.
    """

    def __init__(self, monoid):
        self.monoid = monoid

    def __call__(self, vals, valid, ids, num_segments):
        mono = self.monoid
        with obs_tracing.kernel_scope(
                getattr(self, "_obs_scope", "ppm.fold.ref")):
            valid = valid.astype(bool)
            vals = jnp.where(valid, vals.astype(mono.dtype), mono.identity)
            acc = mono.segment_fold(vals, ids, num_segments)
            touched = jax.ops.segment_max(valid.astype(jnp.int32), ids,
                                          num_segments=num_segments) > 0
            return acc, touched


class RefGather:
    """Pure-jnp gather fold with GatherKernel's exact call contract.

    Unlike the raw :func:`repro.kernels.ref.segment_combine_ref` oracle it
    also applies the 2-level active list (tiles of inactive source
    partitions contribute nothing) and masks invalid slots to the monoid
    identity, so it is interchangeable with the Pallas kernels under the
    engine and under parity tests.

    The call carries a ``custom_vmap`` rule: under a leading query axis
    (the batched multi-source engine path) XLA's default scatter batching
    rule serializes catastrophically on CPU (~100x), so the batched fold
    instead runs the *unbatched* segment ops over a flattened
    ``lane * (n_pad+1) + dst`` segment space — per-lane cost identical to
    the sequential fold, so batching only ever amortizes dispatch.
    """

    def __init__(self, layout, monoid):
        self.monoid = monoid
        self.n_pad = layout.n_pad
        self.edge_dst = jnp.asarray(layout.edge_dst)
        # every edge tile lies inside one (p', p) block: per-edge source
        # partition is the tile's, repeated
        self.edge_src_part = jnp.asarray(
            np.repeat(layout.tile_src_part, layout.edge_tile))
        call = jax.custom_batching.custom_vmap(self._single)
        call.def_vmap(self._vmap_rule)
        self._call = call

    def __call__(self, edge_vals, edge_valid, part_active):
        with obs_tracing.kernel_scope(
                getattr(self, "_obs_scope", "ppm.gather.ref")):
            return self._call(edge_vals, edge_valid, part_active)

    def _single(self, edge_vals, edge_valid, part_active):
        mono = self.monoid
        valid = (edge_valid.astype(bool)
                 & (part_active[self.edge_src_part] > 0))
        vals = jnp.where(valid, edge_vals.astype(mono.dtype), mono.identity)
        acc = mono.segment_fold(vals, self.edge_dst, self.n_pad + 1)
        touched = jax.ops.segment_max(valid.astype(jnp.int32), self.edge_dst,
                                      num_segments=self.n_pad + 1) > 0
        return acc[:self.n_pad], touched[:self.n_pad]

    def _vmap_rule(self, axis_size, in_batched, edge_vals, edge_valid,
                   part_active):
        ev_b, evd_b, pa_b = in_batched
        if not ev_b:
            edge_vals = jnp.broadcast_to(
                edge_vals, (axis_size,) + edge_vals.shape)
        if not evd_b:
            edge_valid = jnp.broadcast_to(
                edge_valid, (axis_size,) + edge_valid.shape)
        if not pa_b:
            part_active = jnp.broadcast_to(
                part_active, (axis_size,) + part_active.shape)
        mono = self.monoid
        B, ns = axis_size, self.n_pad + 1
        valid = (edge_valid.astype(bool)
                 & (jnp.take(part_active, self.edge_src_part, axis=1) > 0))
        vals = jnp.where(valid, edge_vals.astype(mono.dtype), mono.identity)
        # flattened segment space: lane b owns segments [b*ns, (b+1)*ns).
        # The ids stay int32 (segment ops silently drop out-of-range ids,
        # and int64 is unavailable without x64), so lanes are folded in
        # chunks whose flattened space fits int32 — one chunk in practice.
        lanes_per_chunk = max(1, (2**31 - 1) // ns)
        accs, toucheds = [], []
        for lo in range(0, B, lanes_per_chunk):
            bc = min(lanes_per_chunk, B - lo)
            fids = (jnp.arange(bc, dtype=jnp.int32)[:, None] * ns
                    + self.edge_dst[None, :]).reshape(-1)
            v = vals[lo:lo + bc]
            accs.append(mono.segment_fold(
                v.reshape(-1), fids, bc * ns).reshape(bc, ns))
            toucheds.append(jax.ops.segment_max(
                valid[lo:lo + bc].astype(jnp.int32).reshape(-1), fids,
                num_segments=bc * ns).reshape(bc, ns) > 0)
        acc = jnp.concatenate(accs) if len(accs) > 1 else accs[0]
        touched = (jnp.concatenate(toucheds) if len(toucheds) > 1
                   else toucheds[0])
        return (acc[:, :self.n_pad], touched[:, :self.n_pad]), (True, True)


def _edge_src_global(layout) -> np.ndarray:
    """Per-edge *global* source vertex of the gather-order edge stream.

    Every edge tile lies inside one ``(p', p)`` block, so the tile's
    source partition base plus the per-edge local offset recovers the
    global id — the static index the fused kernel gathers the message
    table with (clamped into the sentinel for pad tiles)."""
    base = np.repeat(layout.tile_src_part.astype(np.int64),
                     layout.edge_tile) * layout.q
    src = base + layout.edge_src_local.astype(np.int64)
    return np.clip(src, 0, layout.n_pad).astype(np.int32)


class FusedDCKernel:
    """Fused DC scatter→fold bound to a layout (registry ``fused_dc``).

    One Pallas call replaces the composed scatter kernel + slot gather +
    gather fold of the DC stream: per edge tile the source message is
    gathered straight from the ``[n_pad + 1]`` vertex table (identity
    sentinel last) and folded into the two-level ``[fold_q]``
    sub-accumulators — no ``[NM]`` bin buffer, no ``[NE]`` edge-value
    stream (see :mod:`repro.kernels.fused_step`).

    ``apply_weight`` is engine-configured (the registry does not see the
    program): :class:`repro.core.engine.Engine` sets the attribute once,
    before the step is traced, under the same condition the composed
    path applies it.
    """

    def __init__(self, layout, monoid_name: str, dtype,
                 interpret: bool = True):
        self.L = layout
        self.monoid = monoid_name
        self.dtype = jnp.dtype(dtype)
        self.interpret = interpret
        self.n_pad = layout.n_pad
        self.edge_tile = layout.edge_tile
        self.fold_q = layout.fold_q
        self.edge_src = jnp.asarray(_edge_src_global(layout))
        self.edge_valid = jnp.asarray(layout.edge_valid.astype(np.int32))
        self.edge_dst = jnp.asarray(layout.edge_dst)
        self.edge_w = (jnp.asarray(layout.edge_w)
                       if layout.edge_w is not None else None)
        self.apply_weight = None               # engine-configured

    def __call__(self, table, table_valid):
        aw = self.apply_weight
        with obs_tracing.kernel_scope(
                getattr(self, "_obs_scope", "ppm.fused_dc")):
            return fused_scatter_fold(
                table, table_valid, self.edge_src, self.edge_valid,
                self.edge_dst, self.n_pad + 1, monoid=self.monoid,
                edge_tile=self.edge_tile, fold_q=self.fold_q,
                interpret=self.interpret, apply_weight=aw,
                w=self.edge_w if aw is not None else None)


class RefFusedDC:
    """Pure-jnp fused DC step with FusedDCKernel's exact call contract —
    the composed oracle collapsed to one gather + one segmented fold.

    Carries the same ``custom_vmap`` rule as :class:`RefGather` (the
    batched multi-source engine path): the table gather batches fine,
    but the segment fold would hit XLA's catastrophic scatter batching
    on CPU, so batched lanes fold through a flattened
    ``lane * ns + dst`` segment space instead.
    """

    def __init__(self, layout, monoid):
        self.monoid = monoid
        self.n_pad = layout.n_pad
        self.edge_src = jnp.asarray(_edge_src_global(layout))
        self.edge_valid = jnp.asarray(layout.edge_valid)
        self.edge_dst = jnp.asarray(layout.edge_dst)
        self.edge_w = (jnp.asarray(layout.edge_w)
                       if layout.edge_w is not None else None)
        self.apply_weight = None               # engine-configured
        call = jax.custom_batching.custom_vmap(self._single)
        call.def_vmap(self._vmap_rule)
        self._call = call

    def __call__(self, table, table_valid):
        with obs_tracing.kernel_scope(
                getattr(self, "_obs_scope", "ppm.fused_dc.ref")):
            return self._call(table, table_valid)

    def _single(self, table, table_valid):
        aw = self.apply_weight
        return ref_fused_scatter_fold(
            self.monoid, table, table_valid, self.edge_src,
            self.edge_valid, self.edge_dst, self.n_pad + 1,
            apply_weight=aw, w=self.edge_w if aw is not None else None)

    def _vmap_rule(self, axis_size, in_batched, table, table_valid):
        tb, tvb = in_batched
        if not tb:
            table = jnp.broadcast_to(table, (axis_size,) + table.shape)
        if not tvb:
            table_valid = jnp.broadcast_to(
                table_valid, (axis_size,) + table_valid.shape)
        mono = self.monoid
        B, ns = axis_size, self.n_pad + 1
        vals = jnp.take(table, self.edge_src, axis=1).astype(mono.dtype)
        valid = (jnp.take(table_valid.astype(bool), self.edge_src, axis=1)
                 & self.edge_valid[None, :])
        if self.apply_weight is not None:
            vals = self.apply_weight(
                vals, self.edge_w[None, :]).astype(mono.dtype)
        vals = jnp.where(valid, vals, mono.identity)
        ids = jnp.where(valid, self.edge_dst[None, :], ns - 1)
        # flattened segment space, chunked so bc * ns fits int32 (cf.
        # RefGather._vmap_rule — segment ops silently drop out-of-range
        # ids and int64 is unavailable without x64)
        lanes_per_chunk = max(1, (2**31 - 1) // ns)
        accs, toucheds = [], []
        for lo in range(0, B, lanes_per_chunk):
            bc = min(lanes_per_chunk, B - lo)
            fids = (jnp.arange(bc, dtype=jnp.int32)[:, None] * ns
                    + ids[lo:lo + bc]).reshape(-1)
            accs.append(mono.segment_fold(
                vals[lo:lo + bc].reshape(-1), fids, bc * ns)
                .reshape(bc, ns))
            toucheds.append(jax.ops.segment_max(
                valid[lo:lo + bc].astype(jnp.int32).reshape(-1), fids,
                num_segments=bc * ns).reshape(bc, ns) > 0)
        acc = jnp.concatenate(accs) if len(accs) > 1 else accs[0]
        touched = (jnp.concatenate(toucheds) if len(toucheds) > 1
                   else toucheds[0])
        return (acc, touched), (True, True)


class FusedStreamKernel:
    """Layout-free fused gather→fold with the stream ``fused_dc`` contract.

    What :class:`FoldKernel` is to the fold, this is to the fused step:
    the distributed engine's gather side has no tile/partition structure
    on the receive table (``rv[slot]``), so the kernel takes the table,
    the slot indices and the static validity per call and fuses the slot
    gather + edge function + two-level fold in one Pallas launch.
    """

    def __init__(self, monoid_name: str, dtype, interpret: bool = True,
                 tile=None, q=None):
        self.monoid = monoid_name
        self.dtype = jnp.dtype(dtype)
        self.interpret = interpret
        self.tile = tile
        self.q = q

    def __call__(self, table, table_valid, idx, edge_valid, dst,
                 num_segments, w=None, apply_weight=None):
        tile = int(self.tile) if self.tile else default_fold_tile()
        q = int(self.q) if self.q else default_fold_q()
        with obs_tracing.kernel_scope(
                getattr(self, "_obs_scope", "ppm.fused_dc")):
            return fused_scatter_fold(
                table, table_valid, idx, edge_valid, dst,
                int(num_segments), monoid=self.monoid, edge_tile=tile,
                fold_q=q, interpret=self.interpret,
                apply_weight=apply_weight, w=w)


class RefFusedStream:
    """Pure-jnp stream fused step with FusedStreamKernel's call contract."""

    def __init__(self, monoid):
        self.monoid = monoid

    def __call__(self, table, table_valid, idx, edge_valid, dst,
                 num_segments, w=None, apply_weight=None):
        with obs_tracing.kernel_scope(
                getattr(self, "_obs_scope", "ppm.fused_dc.ref")):
            return ref_fused_scatter_fold(
                self.monoid, table, table_valid, idx, edge_valid, dst,
                int(num_segments), apply_weight=apply_weight, w=w)


class RefScatter:
    """Pure-jnp DC scatter with ScatterKernel's exact call contract."""

    def __init__(self, layout, monoid):
        self.monoid = monoid
        self.n_pad = layout.n_pad
        self.png_src = jnp.asarray(layout.png_src)
        self.png_valid = jnp.asarray(layout.png_src < layout.n_pad)

    def __call__(self, x_flat, active_flat):
        mono = self.monoid
        with obs_tracing.kernel_scope(
                getattr(self, "_obs_scope", "ppm.scatter.ref")):
            src = jnp.minimum(self.png_src, self.n_pad - 1)
            ok = self.png_valid & (active_flat.astype(bool)[src])
            return jnp.where(ok, x_flat.astype(mono.dtype)[src],
                             mono.identity)


class RefSpmv:
    """Pure-jnp partition-centric SpMV with SpmvKernel's call contract."""

    def __init__(self, layout, weighted=None):
        self.n_pad = layout.n_pad
        self.weighted = layout.weighted if weighted is None else weighted
        self.msg_slot = jnp.asarray(layout.msg_slot)
        self.png_src = jnp.asarray(layout.png_src)
        self.edge_dst = jnp.asarray(layout.edge_dst)
        self.edge_valid = jnp.asarray(layout.edge_valid)
        self.edge_w = (jnp.asarray(layout.edge_w)
                       if (self.weighted and layout.edge_w is not None)
                       else None)

    def __call__(self, x_flat):
        with obs_tracing.kernel_scope(
                getattr(self, "_obs_scope", "ppm.spmv.ref")):
            return kref.spmv_block_ref(
                x_flat, self.msg_slot, self.png_src, self.edge_dst,
                self.edge_valid, self.edge_w, self.n_pad)


def make_kernels(layout, monoid, backend=None, platform=None,
                 with_spmv=False):
    """Construct the engine-facing kernel set through the backend registry."""
    from ..backend import registry
    return registry.make_kernels(layout, monoid, backend=backend,
                                 platform=platform, with_spmv=with_spmv)


__all__ = ["GatherKernel", "ScatterKernel", "SpmvKernel", "FoldKernel",
           "FusedDCKernel", "FusedStreamKernel", "RefGather", "RefScatter",
           "RefSpmv", "RefFold", "RefFusedDC", "RefFusedStream",
           "make_kernels", "segment_combine", "dc_gather", "spmv_block",
           "blocked_segment_fold", "two_level_segment_fold",
           "fused_scatter_fold", "ref_fused_scatter_fold", "fused_enabled",
           "kref"]
