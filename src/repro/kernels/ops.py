"""Layout-bound jit wrappers around the Pallas kernels.

``GatherKernel`` / ``ScatterKernel`` bind a :class:`repro.graph.layout.Layout`
once (moving the static bin-grid geometry to device) and expose the engine-
facing API.  ``interpret=True`` runs the kernel bodies on CPU for validation;
on TPU hardware the same calls compile to Mosaic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import ref as kref
from .dc_gather import dc_gather
from .segment_combine import segment_combine, _identity_val
from .spmv_block import spmv_block


class GatherKernel:
    """Gather-phase fold bound to a layout (acc + touched over [n_pad])."""

    def __init__(self, layout, monoid_name: str, dtype,
                 interpret: bool = True):
        self.L = layout
        self.monoid = monoid_name
        self.dtype = jnp.dtype(dtype)
        self.interpret = interpret
        self.tile_dst_part = jnp.asarray(layout.tile_dst_part)
        self.tile_src_part = jnp.asarray(layout.tile_src_part)
        self.tile_first = jnp.asarray(layout.tile_first.astype(np.int32))
        self.edge_dst_local = jnp.asarray(layout.edge_dst_local)
        self.has_tiles = jnp.asarray(
            layout.part_has_tiles.astype(np.int32))[:, None]
        self.ident = _identity_val(monoid_name, self.dtype)

    def __call__(self, edge_vals, edge_valid, part_active):
        L = self.L
        acc, touched = segment_combine(
            edge_vals, edge_valid, self.edge_dst_local,
            self.tile_dst_part, self.tile_src_part, self.tile_first,
            part_active, k=L.k, q=L.q, edge_tile=L.edge_tile,
            monoid=self.monoid, interpret=self.interpret)
        # destination partitions with no incoming tiles were never visited
        acc = jnp.where(self.has_tiles > 0, acc, self.ident)
        touched = jnp.where(self.has_tiles > 0, touched, 0)
        return acc.reshape(-1), touched.reshape(-1) > 0


class ScatterKernel:
    """DC scatter-phase message materialization bound to a layout."""

    def __init__(self, layout, monoid_name: str, dtype,
                 interpret: bool = True):
        self.L = layout
        self.monoid = monoid_name
        self.dtype = jnp.dtype(dtype)
        self.interpret = interpret
        self.png_src_local = jnp.asarray(layout.png_src_local)
        self.png_valid = jnp.asarray(
            (layout.png_src < layout.n_pad).astype(np.int32))
        self.png_tile_part = jnp.asarray(layout.png_tile_part)

    def __call__(self, x_flat, active_flat):
        L = self.L
        return dc_gather(
            x_flat.reshape(L.k, L.q),
            active_flat.astype(jnp.int32).reshape(L.k, L.q),
            self.png_src_local, self.png_valid, self.png_tile_part,
            k=L.k, q=L.q, msg_tile=L.msg_tile, monoid=self.monoid,
            interpret=self.interpret)


class SpmvKernel:
    """Fused partition-centric SpMV bound to a layout (PageRank DC loop)."""

    def __init__(self, layout, interpret: bool = True, weighted=None):
        self.L = layout
        self.interpret = interpret
        self.weighted = layout.weighted if weighted is None else weighted
        self.edge_src_local = jnp.asarray(layout.edge_src_local)
        self.edge_dst_local = jnp.asarray(layout.edge_dst_local)
        self.edge_valid = jnp.asarray(layout.edge_valid.astype(np.int32))
        self.edge_w = (jnp.asarray(layout.edge_w)
                       if (self.weighted and layout.edge_w is not None)
                       else None)
        self.tile_dst_part = jnp.asarray(layout.tile_dst_part)
        self.tile_src_part = jnp.asarray(layout.tile_src_part)
        self.tile_first = jnp.asarray(layout.tile_first.astype(np.int32))
        self.has_tiles = jnp.asarray(
            layout.part_has_tiles.astype(np.int32))[:, None]

    def __call__(self, x_flat):
        L = self.L
        y = spmv_block(
            x_flat.reshape(L.k, L.q), self.edge_src_local,
            self.edge_dst_local, self.edge_valid, self.edge_w,
            self.tile_dst_part, self.tile_src_part, self.tile_first,
            k=L.k, q=L.q, edge_tile=L.edge_tile,
            weighted=self.edge_w is not None, interpret=self.interpret)
        return jnp.where(self.has_tiles > 0, y, 0.0).reshape(-1)


__all__ = ["GatherKernel", "ScatterKernel", "SpmvKernel",
           "segment_combine", "dc_gather", "spmv_block", "kref"]
