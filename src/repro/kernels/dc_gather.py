"""Scatter-phase DC message materialization as a Pallas kernel.

The paper's DC Scatter streams the PNG layout of partition ``p`` and writes
*data-only* messages sequentially into the bin row (§3.3, Alg. 2).  Here the
grid walks message-slot tiles (row-major (p, p') order = writing ``bin[p][:]``
sequentially); the source partition's value tile and activity tile are
VMEM-resident (blocked by the scalar-prefetched ``png_tile_part``), and each
slot gathers its source's value — identity for inactive or padding slots.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .segment_combine import _identity_val


def _kernel(tile_part_ref,                       # scalar prefetch
            x_ref, act_ref, srcl_ref, valid_ref,  # VMEM in
            out_ref, *, monoid: str):
    ident = _identity_val(monoid, out_ref.dtype)
    srcl = srcl_ref[...]                          # [T] local src ids
    x = x_ref[0, :]                               # [q] partition values
    act = act_ref[0, :]                           # [q] partition activity
    vals = x[srcl]
    ok = (valid_ref[...] > 0) & (act[srcl] > 0)
    out_ref[...] = jnp.where(ok, vals, ident)


@functools.partial(jax.jit, static_argnames=("k", "q", "msg_tile", "monoid",
                                             "interpret"))
def dc_gather(x, active, png_src_local, png_valid, png_tile_part,
              *, k: int, q: int, msg_tile: int, monoid: str = "add",
              interpret: bool = True):
    """Materialize the DC message buffer.

    Args:
      x:              [k, q] per-vertex scatter values (already scatter_fn'd).
      active:         [k, q] int32 per-vertex activity.
      png_src_local:  [NM] int32 source id within its partition.
      png_valid:      [NM] int32 slot validity (0 on pads).
      png_tile_part:  [NTM] int32 source partition per slot tile.
    Returns:
      msg values [NM] (identity on invalid/inactive slots).
    """
    ntm = png_tile_part.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(ntm,),
        in_specs=[
            pl.BlockSpec((1, q), lambda t, tp: (tp[t], 0)),
            pl.BlockSpec((1, q), lambda t, tp: (tp[t], 0)),
            pl.BlockSpec((msg_tile,), lambda t, tp: (t,)),
            pl.BlockSpec((msg_tile,), lambda t, tp: (t,)),
        ],
        out_specs=pl.BlockSpec((msg_tile,), lambda t, tp: (t,)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, monoid=monoid),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((png_src_local.shape[0],), x.dtype),
        interpret=interpret,
    )(png_tile_part, x, active.astype(jnp.int32),
      png_src_local, png_valid.astype(jnp.int32))
