"""Gather-phase segmented monoid fold — the PPM hot loop, as a Pallas kernel.

TPU mapping of the paper's Gather phase (§3.2):

  * the grid walks gather-order (destination-major) edge tiles — reading
    ``bin[:][p']`` column-wise exactly like the paper;
  * the destination partition's accumulator tile (``q`` vertices) stays
    resident in VMEM across all tiles of that partition — the paper's
    "vertex data of partition p fits the private cache";
  * tiles whose *source* partition has no active vertices are skipped with
    ``pl.when`` — grid-level predication is the TPU realization of the
    2-level active list (``binPartList``);
  * the per-tile destination block index comes from a scalar-prefetched
    ``tile_dst_part`` array (the static bin-grid geometry).

The ``add`` fold uses an MXU-friendly one-hot matmul; ``min``/``max`` use a
masked VPU reduce.  Outputs are (acc[k, q], touched[k, q]).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
import numpy as np


def _identity_val(monoid: str, dtype):
    if monoid == "add":
        return np.zeros((), dtype)
    if monoid == "min":
        return (np.array(np.inf, dtype) if jnp.issubdtype(dtype, jnp.floating)
                else np.array(np.iinfo(dtype).max, dtype))
    if monoid == "max":
        return (np.array(-np.inf, dtype) if jnp.issubdtype(dtype, jnp.floating)
                else np.array(np.iinfo(dtype).min, dtype))
    raise ValueError(monoid)


def _kernel(tile_dst_ref, tile_src_ref, tile_first_ref,   # scalar prefetch
            part_active_ref,                               # scalar prefetch
            vals_ref, valid_ref, dstl_ref,                 # VMEM in
            acc_ref, touched_ref,                          # VMEM out
            *, monoid: str, q: int):
    t = pl.program_id(0)
    ident = _identity_val(monoid, acc_ref.dtype)

    # first tile of this destination partition: initialize the accumulator
    @pl.when(tile_first_ref[t] > 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, ident)
        touched_ref[...] = jnp.zeros_like(touched_ref)

    # 2-level active list: skip tiles whose source partition is inactive
    @pl.when(part_active_ref[tile_src_ref[t]] > 0)
    def _body():
        vals = vals_ref[...]                                # [T]
        valid = valid_ref[...] > 0                          # [T]
        dstl = dstl_ref[...]                                # [T]
        cols = jax.lax.broadcasted_iota(jnp.int32, (vals.shape[0], q), 1)
        onehot = (dstl[:, None] == cols) & valid[:, None]   # [T, q]
        if monoid == "add":
            if jnp.issubdtype(acc_ref.dtype, jnp.floating):
                contrib = jnp.dot(
                    jnp.where(valid, vals, 0).astype(jnp.float32)[None, :],
                    onehot.astype(jnp.float32),
                    preferred_element_type=jnp.float32)[0]
            else:
                # 32-bit integer state: the f32 MXU round trip truncates
                # above 2**24, so fold on the VPU in the native dtype
                masked = jnp.where(onehot, vals[:, None],
                                   jnp.zeros((), acc_ref.dtype))
                contrib = jnp.sum(masked, axis=0)
            acc_ref[...] = acc_ref[...] + contrib.astype(acc_ref.dtype)[None, :]
        elif monoid == "min":
            masked = jnp.where(onehot, vals[:, None], ident)
            acc_ref[...] = jnp.minimum(acc_ref[...],
                                       jnp.min(masked, axis=0)[None, :])
        elif monoid == "max":
            masked = jnp.where(onehot, vals[:, None], ident)
            acc_ref[...] = jnp.maximum(acc_ref[...],
                                       jnp.max(masked, axis=0)[None, :])
        touched_ref[...] = jnp.maximum(
            touched_ref[...],
            jnp.max(onehot.astype(jnp.int32), axis=0)[None, :])


@functools.partial(jax.jit, static_argnames=("k", "q", "edge_tile", "monoid",
                                             "interpret"))
def segment_combine(edge_vals, edge_valid, edge_dst_local,
                    tile_dst_part, tile_src_part, tile_first,
                    part_active, *, k: int, q: int, edge_tile: int,
                    monoid: str = "add", interpret: bool = True):
    """Fold edge messages into per-partition accumulators.

    Args:
      edge_vals:      [NE] message value per edge (gather order).
      edge_valid:     [NE] int32 validity (pads & inactive-source slots = 0).
      edge_dst_local: [NE] int32 destination id within its partition.
      tile_dst_part / tile_src_part / tile_first: [NT] int32 tile geometry.
      part_active:    [k] int32 source-partition activity (gPartList).
    Returns:
      acc [k, q] monoid fold, touched [k, q] int32.
    """
    nt = tile_dst_part.shape[0]
    dtype = edge_vals.dtype
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((edge_tile,), lambda t, *pf: (t,)),
            pl.BlockSpec((edge_tile,), lambda t, *pf: (t,)),
            pl.BlockSpec((edge_tile,), lambda t, *pf: (t,)),
        ],
        out_specs=[
            pl.BlockSpec((1, q), lambda t, td, ts, tf, pa: (td[t], 0)),
            pl.BlockSpec((1, q), lambda t, td, ts, tf, pa: (td[t], 0)),
        ],
    )
    acc, touched = pl.pallas_call(
        functools.partial(_kernel, monoid=monoid, q=q),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((k, q), dtype),
                   jax.ShapeDtypeStruct((k, q), jnp.int32)],
        interpret=interpret,
    )(tile_dst_part, tile_src_part, tile_first.astype(jnp.int32),
      part_active.astype(jnp.int32),
      edge_vals, edge_valid.astype(jnp.int32), edge_dst_local)
    return acc, touched
