"""Transformer building blocks: RMSNorm, RoPE, chunked GQA attention, SwiGLU.

Everything is pure-functional over (params pytree, inputs).  Attention is
chunked with an online softmax (flash-attention structure in XLA) so that
32k-token prefill never materializes an S x S score matrix.  Param init
functions return ``(params, logical_axes)`` twin pytrees; the mapping from
logical axes to mesh axes lives in ``repro.dist.sharding``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.sharding import constrain

# ----------------------------------------------------------------------
# param helpers
# ----------------------------------------------------------------------

def _init(key, shape, scale=None, dtype=jnp.float32):
    if scale is None:
        scale = 1.0 / np.sqrt(shape[-2] if len(shape) >= 2 else shape[-1])
    return jax.random.normal(key, shape, dtype) * scale


def dense_param(key, d_in, d_out, axes, n_layers=None, scale=None):
    shape = (d_in, d_out) if n_layers is None else (n_layers, d_in, d_out)
    ax = axes if n_layers is None else ("layers",) + axes
    return _init(key, shape, scale), ax


# ----------------------------------------------------------------------
# norms / rotary
# ----------------------------------------------------------------------

def rms_norm(x, w, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * w.astype(x.dtype)


def rope(x, positions, theta=10_000.0):
    """Rotary embedding.  x: [..., S, H, dh]; positions: [..., S]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..,S,half]
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# chunked attention (online softmax)
# ----------------------------------------------------------------------

NEG_INF = -1e30


def chunked_attention(q, k, v, *, causal: bool = True,
                      window: Optional[int] = None, q_offset: int = 0,
                      q_chunk: int = 1024, kv_chunk: int = 1024):
    """GQA attention with flash-style chunking (contiguous positions).

    q: [B, Sq, H, dh]; k, v: [B, Skv, KV, dh]; H % KV == 0.
    Never materializes more than [B, H, q_chunk, kv_chunk] scores.  Masks are
    derived from the *loop indices* inside checkpointed scan bodies, so XLA
    can neither hoist a [nq, nkv, qc, kc] mask tensor out of the loops nor
    stack per-step masks as backward residuals (both were multi-GB/TB
    buffers in early dry-runs — see EXPERIMENTS.md §Perf).
    """
    B, Sq, H, dh = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    nq = -(-Sq // q_chunk)
    nkv = -(-Skv // kv_chunk)
    qc_pad = nq * q_chunk
    kc_pad = nkv * kv_chunk
    scale = 1.0 / np.sqrt(dh)

    qp = jnp.pad(q, ((0, 0), (0, qc_pad - Sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, kc_pad - Skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, kc_pad - Skv), (0, 0), (0, 0)))

    qs = qp.reshape(B, nq, q_chunk, H, dh).transpose(1, 0, 3, 2, 4)
    ks = kp.reshape(B, nkv, kv_chunk, KV, dh).transpose(1, 0, 2, 3, 4)
    vs = vp.reshape(B, nkv, kv_chunk, KV, dh).transpose(1, 0, 2, 3, 4)

    def q_step(_, qin):
        qi, iq = qin                          # [B,H,qc,dh], scalar index
        qpos = iq * q_chunk + jnp.arange(q_chunk) + q_offset

        def kv_step(carry, kin):
            m, l, acc = carry
            kj, vj, jk = kin                  # [B,kc,KV,dh] x2, index
            kpos = jk * kv_chunk + jnp.arange(kv_chunk)
            kj = kj.transpose(0, 2, 1, 3)     # [B,KV,kc,dh]
            vj = vj.transpose(0, 2, 1, 3)
            qg = qi.reshape(B, KV, G, q_chunk, dh)
            s = jnp.einsum("bkgqd,bkcd->bkgqc", qg.astype(jnp.float32),
                           kj.astype(jnp.float32)) * scale
            mask = (kpos < Skv)[None, :]
            if causal:
                mask = mask & (qpos[:, None] >= kpos[None, :])
            if window is not None:
                mask = mask & (qpos[:, None] - kpos[None, :] < window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqc,bkcd->bkgqd", p,
                            vj.astype(jnp.float32))
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_chunk, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step), (m0, l0, a0),
            (ks, vs, jnp.arange(nkv)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.reshape(B, H, q_chunk, dh)

    _, outs = jax.lax.scan(jax.checkpoint(q_step), None,
                           (qs, jnp.arange(nq)))   # [nq,B,H,qc,dh]
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, qc_pad, H, dh)
    return out[:, :Sq].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, *, q_position, kv_positions,
                     kv_valid, window: Optional[int] = None):
    """Single-step attention against a KV cache.  q: [B, 1, H, dh]."""
    B, _, H, dh = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    scale = 1.0 / np.sqrt(dh)
    qg = q[:, 0].reshape(B, KV, G, dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    mask = kv_valid[:, None, None, :] & \
        (kv_positions[:, None, None, :] <= q_position[:, None, None, None])
    if window is not None:
        mask = mask & (q_position[:, None, None, None]
                       - kv_positions[:, None, None, :] < window)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, dh).astype(q.dtype)


# ----------------------------------------------------------------------
# GQA attention layer
# ----------------------------------------------------------------------

def attention_params(key, cfg, n_layers=None, prefix_shared=False):
    d, H, KV, dh = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_head
    d_in = 2 * d if prefix_shared else d    # zamba2 concat(hidden, residual)
    ks = jax.random.split(key, 5)
    p, a = {}, {}
    p["wq"], a["wq"] = dense_param(ks[0], d_in, H * dh, ("embed", "heads"),
                                   n_layers)
    p["wk"], a["wk"] = dense_param(ks[1], d_in, KV * dh, ("embed", "kv"),
                                   n_layers)
    p["wv"], a["wv"] = dense_param(ks[2], d_in, KV * dh, ("embed", "kv"),
                                   n_layers)
    p["wo"], a["wo"] = dense_param(ks[3], H * dh, d, ("heads", "embed"),
                                   n_layers)
    if cfg.qkv_bias:
        shp = (H * dh,) if n_layers is None else (n_layers, H * dh)
        shk = (KV * dh,) if n_layers is None else (n_layers, KV * dh)
        ax1 = ("heads",) if n_layers is None else ("layers", "heads")
        ax2 = ("kv",) if n_layers is None else ("layers", "kv")
        p["bq"], a["bq"] = jnp.zeros(shp), ax1
        p["bk"], a["bk"] = jnp.zeros(shk), ax2
        p["bv"], a["bv"] = jnp.zeros(shk), ax2
    return p, a


def attention_fwd(p, cfg, x, positions, *, window=None, dtype=jnp.bfloat16):
    """Full-sequence attention (train / prefill).  x: [B, S, d_in]."""
    B, S, _ = x.shape
    H, KV, dh = cfg.n_heads, cfg.n_kv, cfg.d_head
    ah = cfg.act_axis("heads")
    q = constrain(x @ p["wq"].astype(dtype), "batch", None, ah)
    k = constrain(x @ p["wk"].astype(dtype), "batch", None,
                  cfg.act_axis("kv"))
    v = constrain(x @ p["wv"].astype(dtype), "batch", None,
                  cfg.act_axis("kv"))
    q = q.reshape(B, S, H, dh)
    k = k.reshape(B, S, KV, dh)
    v = v.reshape(B, S, KV, dh)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dtype).reshape(H, dh)
        k = k + p["bk"].astype(dtype).reshape(KV, dh)
        v = v + p["bv"].astype(dtype).reshape(KV, dh)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    out = chunked_attention(q, k, v, causal=cfg.causal, window=window)
    out = constrain(out.reshape(B, S, H * dh), "batch", None, ah)
    return constrain(out @ p["wo"].astype(dtype),
                     "batch", None, None), (k, v)


# ----------------------------------------------------------------------
# SwiGLU MLP
# ----------------------------------------------------------------------

def mlp_params(key, d, ff, n_layers=None):
    ks = jax.random.split(key, 3)
    p, a = {}, {}
    p["w1"], a["w1"] = dense_param(ks[0], d, ff, ("embed", "ff"), n_layers)
    p["w3"], a["w3"] = dense_param(ks[1], d, ff, ("embed", "ff"), n_layers)
    p["w2"], a["w2"] = dense_param(ks[2], ff, d, ("ff", "embed"), n_layers)
    return p, a


def mlp_fwd(p, x, dtype=jnp.bfloat16, constrained: bool = True):
    # constrained=False inside shard_map bodies (with_sharding_constraint
    # may not name manual mesh axes)
    h = jax.nn.silu(x @ p["w1"].astype(dtype)) * (x @ p["w3"].astype(dtype))
    if constrained:
        h = constrain(h, "batch", None, "model")
        return constrain(h @ p["w2"].astype(dtype), "batch", None, None)
    return h @ p["w2"].astype(dtype)
