"""Mamba2 / SSD (state-space duality) blocks — chunked, MXU-friendly.

The SSD algorithm (Dao & Gu, 2024) decomposes the selective-state recurrence
into (a) intra-chunk quadratic attention-like matmuls and (b) an inter-chunk
state recurrence (a short scan over chunks) — exactly the layout a TPU wants:
all heavy math is batched matmuls; the only sequential piece is length L/Q.

Single-group (G=1) B/C as in mamba2 defaults.  ``ssd_sequential_ref`` is the
step-by-step oracle used in tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.sharding import constrain
from .layers import dense_param, rms_norm


# ----------------------------------------------------------------------
# params
# ----------------------------------------------------------------------

def ssm_params(key, cfg, n_layers=None):
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H, K = cfg.ssm_heads, cfg.ssm_conv
    ks = jax.random.split(key, 8)
    p, a = {}, {}
    p["wz"], a["wz"] = dense_param(ks[0], d, di, ("embed", "ssm_inner"),
                                   n_layers)
    p["wx"], a["wx"] = dense_param(ks[1], d, di, ("embed", "ssm_inner"),
                                   n_layers)
    p["wB"], a["wB"] = dense_param(ks[2], d, N, ("embed", None), n_layers)
    p["wC"], a["wC"] = dense_param(ks[3], d, N, ("embed", None), n_layers)
    p["wdt"], a["wdt"] = dense_param(ks[4], d, H, ("embed", "ssm_heads"),
                                     n_layers)

    def vec(shape, ax, val):
        shp = shape if n_layers is None else (n_layers,) + shape
        ax_ = ax if n_layers is None else ("layers",) + ax
        return jnp.full(shp, val, jnp.float32), ax_

    p["dt_bias"], a["dt_bias"] = vec((H,), ("ssm_heads",), 0.0)
    p["A_log"], a["A_log"] = vec((H,), ("ssm_heads",), 0.0)
    p["D"], a["D"] = vec((H,), ("ssm_heads",), 1.0)
    p["conv_x"], a["conv_x"] = (
        _conv_init(ks[5], K, di, n_layers), _conv_ax(n_layers, "ssm_inner"))
    p["conv_B"], a["conv_B"] = (
        _conv_init(ks[6], K, N, n_layers), _conv_ax(n_layers, None))
    p["conv_C"], a["conv_C"] = (
        _conv_init(ks[7], K, N, n_layers), _conv_ax(n_layers, None))
    p["norm"], a["norm"] = vec((di,), ("ssm_inner",), 1.0)
    p["out"], a["out"] = dense_param(
        jax.random.fold_in(key, 99), di, d, ("ssm_inner", "embed"), n_layers)
    return p, a


def _conv_init(key, K, ch, n_layers):
    shape = (K, ch) if n_layers is None else (n_layers, K, ch)
    return jax.random.normal(key, shape) / np.sqrt(K)


def _conv_ax(n_layers, ch_ax):
    return (None, ch_ax) if n_layers is None else ("layers", None, ch_ax)


# ----------------------------------------------------------------------
# causal depthwise conv
# ----------------------------------------------------------------------

def causal_conv(x, w):
    """x: [B, L, C]; w: [K, C] depthwise causal convolution.

    Single fused conv op (feature-grouped) instead of K shifted
    multiply-adds: 8x fewer tensor-boundary ops per block, and the form the
    TPU conv unit actually wants."""
    K, C = w.shape
    out = jax.lax.conv_general_dilated(
        x.astype(w.dtype) if x.dtype != w.dtype else x,
        w.reshape(K, 1, C).astype(x.dtype),
        window_strides=(1,), padding=[(K - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=C)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# chunked SSD
# ----------------------------------------------------------------------

def ssd_chunked(x, dt, A, B, C, D, chunk: int, h0=None,
                intra_bf16: bool = False):
    """Chunked selective-state-space computation.

    x: [B, L, H, P]; dt: [B, L, H] (already softplus'd); A: [H] (negative);
    B, C: [B, L, N] (single group); D: [H].
    Returns (y [B, L, H, P], final_state [B, H, N, P]).
    """
    Bz, L, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, L)
    nc = -(-L // Q)
    pad = nc * Q - L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    f32 = jnp.float32
    adt = jnp.bfloat16 if intra_bf16 else f32   # bulk-activation dtype
    # chunk-major layout for the scan: [nc, B, Q, ...]
    xc = x.reshape(Bz, nc, Q, H, P).transpose(1, 0, 2, 3, 4).astype(adt)
    dtc = dt.reshape(Bz, nc, Q, H).transpose(1, 0, 2, 3).astype(f32)
    Bc = B.reshape(Bz, nc, Q, N).transpose(1, 0, 2, 3).astype(adt)
    Cc = C.reshape(Bz, nc, Q, N).transpose(1, 0, 2, 3).astype(adt)
    mask = (jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :])
    Af = A.astype(f32)
    Df = D.astype(f32)

    idt = jnp.bfloat16 if intra_bf16 else f32

    def step(h, inp):
        x_c, dt_c, B_c, C_c = inp         # [B,Q,H,P], [B,Q,H], [B,Q,N] x2
        a = dt_c * Af                     # [B,Q,H] log decay
        cum = jnp.cumsum(a, axis=1)
        xdt = x_c * dt_c[..., None].astype(x_c.dtype)
        # intra-chunk (masked attention-like matmul); exponentials stay f32,
        # the big [B,Q,Q,H] operand optionally travels as bf16
        scores = jnp.einsum("bin,bjn->bij", C_c, B_c,
                            preferred_element_type=f32)        # [B,Q,Q]
        Lmat = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])
        Lmat = jnp.where(mask[None, :, :, None], Lmat, 0.0)    # [B,i,j,H]
        y = jnp.einsum("bij,bijh,bjhp->bihp",
                       scores.astype(idt), Lmat.astype(idt),
                       xdt.astype(idt),
                       preferred_element_type=f32)
        # contribution of the incoming state
        y = y + jnp.einsum("bin,bih,bhnp->bihp",
                           C_c.astype(f32), jnp.exp(cum), h)
        y = y + Df[None, None, :, None] * x_c.astype(f32)
        # state update
        last = cum[:, -1:, :]                                  # [B,1,H]
        S_c = jnp.einsum("bjn,bjh,bjhp->bhnp", B_c.astype(f32),
                         jnp.exp(last - cum), xdt.astype(f32))
        h = h * jnp.exp(last[:, 0, :])[..., None, None] + S_c
        return h, y

    h_init = (jnp.zeros((Bz, H, N, P), f32) if h0 is None
              else h0.astype(f32))
    hT, ys = jax.lax.scan(step, h_init, (xc, dtc, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bz, nc * Q, H, P)[:, :L]
    return y.astype(x.dtype), hT


def ssd_sequential_ref(x, dt, A, B, C, D, h0=None):
    """Step-by-step oracle: h_t = e^{dt_t A} h_{t-1} + dt_t B_t x_t."""
    Bz, L, H, P = x.shape
    N = B.shape[-1]
    h = (jnp.zeros((Bz, H, N, P), jnp.float32) if h0 is None
         else h0.astype(jnp.float32))
    ys = []
    for t in range(L):
        dec = jnp.exp(dt[:, t] * A)                            # [B,H]
        h = h * dec[..., None, None] + jnp.einsum(
            "bn,bhp,bh->bhnp", B[:, t], x[:, t].astype(jnp.float32),
            dt[:, t])
        y = jnp.einsum("bn,bhnp->bhp", C[:, t], h) \
            + D[None, :, None] * x[:, t].astype(jnp.float32)
        ys.append(y)
    return jnp.stack(ys, axis=1).astype(x.dtype), h


# ----------------------------------------------------------------------
# full Mamba2 block
# ----------------------------------------------------------------------

def ssm_block_fwd(p, cfg, x, *, dtype=jnp.bfloat16, h0=None, conv0=None,
                  return_state: bool = False):
    """x: [B, L, d_model] -> [B, L, d_model] (+ optional states)."""
    Bz, L, _ = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z = constrain(x @ p["wz"].astype(dtype), "batch", None, "model")
    xin = constrain(x @ p["wx"].astype(dtype), "batch", None, "model")
    Bv = x @ p["wB"].astype(dtype)
    Cv = x @ p["wC"].astype(dtype)
    dt = jax.nn.softplus(
        (x @ p["wdt"].astype(dtype)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    xBC = jnp.concatenate([xin, Bv, Cv], axis=-1)
    convw = jnp.concatenate(
        [p["conv_x"], p["conv_B"], p["conv_C"]], axis=-1)
    if conv0 is not None:
        xBC_pre = jnp.concatenate([conv0.astype(dtype), xBC], axis=1)
        xBC = causal_conv(xBC_pre, convw)[:, conv0.shape[1]:]
    else:
        xBC_pre = xBC
        xBC = causal_conv(xBC, convw)
    xBC = jax.nn.silu(xBC)
    xin, Bv, Cv = jnp.split(xBC, [di, di + N], axis=-1)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, hT = ssd_chunked(xin.reshape(Bz, L, H, P), dt, A,
                        Bv.astype(jnp.float32), Cv.astype(jnp.float32),
                        p["D"].astype(jnp.float32), cfg.ssm_chunk, h0=h0,
                        intra_bf16=cfg.ssm_intra_bf16)
    y = constrain(y.reshape(Bz, L, di), "batch", None, "model")
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = constrain(y @ p["out"].astype(dtype), "batch", None, None)
    if return_state:
        K = cfg.ssm_conv
        # conv state holds the PRE-activation xBC history
        return out, hT, xBC_pre[:, -(K - 1):]
    return out


def ssm_block_decode(p, cfg, x, h, conv_state, *, dtype=jnp.bfloat16):
    """Single-token decode.  x: [B, 1, d]; h: [B,H,N,P];
    conv_state: [B, K-1, di+2N] (pre-activation xBC history)."""
    Bz = x.shape[0]
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z = x @ p["wz"].astype(dtype)
    xin = x @ p["wx"].astype(dtype)
    Bv = x @ p["wB"].astype(dtype)
    Cv = x @ p["wC"].astype(dtype)
    dt = jax.nn.softplus(
        (x @ p["wdt"].astype(dtype)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))[:, 0]              # [B,H]
    xBC = jnp.concatenate([xin, Bv, Cv], axis=-1)              # [B,1,di+2N]
    hist = jnp.concatenate([conv_state.astype(dtype), xBC], axis=1)
    convw = jnp.concatenate(
        [p["conv_x"], p["conv_B"], p["conv_C"]], axis=-1)      # [K, ch]
    conv_out = jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32),
                          convw.astype(jnp.float32))
    conv_out = jax.nn.silu(conv_out)
    xin1, Bv1, Cv1 = jnp.split(conv_out, [di, di + N], axis=-1)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dec = jnp.exp(dt * A)                                      # [B,H]
    xh = xin1.reshape(Bz, H, P)
    h = h * dec[..., None, None] + jnp.einsum(
        "bn,bhp,bh->bhnp", Bv1, xh, dt)
    y = jnp.einsum("bn,bhnp->bhp", Cv1, h) \
        + p["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(Bz, 1, di)
    y = rms_norm(y.astype(dtype) * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out"].astype(dtype)
    new_conv = hist[:, 1:]
    return out, h, new_conv
