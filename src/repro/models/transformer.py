"""Full LM assembly: embedding -> scanned blocks -> tied head.

Families:
  dense / moe          pre-norm GQA attention + SwiGLU / MoE
  ssm                  Mamba2 (SSD) blocks, attention-free
  hybrid (zamba2)      Mamba2 backbone + ONE weight-shared attention+MLP
                       block invoked every ``attn_every`` layers on
                       concat(hidden, initial_embedding)
  vlm / audio          stub frontend: precomputed patch/frame embeddings
                       (projected) feed the text backbone

Layers are stacked and scanned (compile-time O(1) in depth); remat wraps the
scan body.  Caches are layer-stacked pytrees threaded through the same scan.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import moe as moe_lib
from . import ssm as ssm_lib
from ..dist.sharding import constrain
from .config import ModelConfig
from .layers import (attention_fwd, attention_params, chunked_attention,
                     decode_attention, mlp_fwd, mlp_params, rms_norm, rope)


# ----------------------------------------------------------------------
# init
# ----------------------------------------------------------------------

def init_lm(cfg: ModelConfig, key):
    ks = jax.random.split(key, 8)
    L, d = cfg.n_layers, cfg.d_model
    p = {"embed": jax.random.normal(ks[0], (cfg.vocab, d)) * 0.02}
    a = {"embed": ("vocab", "embed")}
    p["final_norm"] = jnp.ones((d,))
    a["final_norm"] = (None,)
    layers_p, layers_a = {}, {}
    if cfg.family in ("ssm", "hybrid"):
        sp, sa = ssm_lib.ssm_params(ks[1], cfg, n_layers=L)
        layers_p["ssm"], layers_a["ssm"] = sp, sa
        layers_p["ln"] = jnp.ones((L, d))
        layers_a["ln"] = ("layers", None)
        if cfg.family == "hybrid" and cfg.attn_every:
            shp, sha = {}, {}
            ap, aa = attention_params(ks[2], cfg, prefix_shared=True)
            shp["attn"], sha["attn"] = ap, aa
            mp, ma = mlp_params(ks[3], d, cfg.d_ff)
            shp["mlp"], sha["mlp"] = mp, ma
            shp["ln1"] = jnp.ones((2 * d,))
            sha["ln1"] = (None,)
            shp["ln2"] = jnp.ones((d,))
            sha["ln2"] = (None,)
            p["shared"], a["shared"] = shp, sha
    else:
        ap, aa = attention_params(ks[1], cfg, n_layers=L)
        layers_p["attn"], layers_a["attn"] = ap, aa
        if cfg.is_moe:
            mp, ma = moe_lib.moe_params(ks[2], cfg, n_layers=L)
            layers_p["moe"], layers_a["moe"] = mp, ma
        else:
            mp, ma = mlp_params(ks[2], d, cfg.d_ff, n_layers=L)
            layers_p["mlp"], layers_a["mlp"] = mp, ma
        layers_p["ln1"] = jnp.ones((L, d))
        layers_a["ln1"] = ("layers", None)
        layers_p["ln2"] = jnp.ones((L, d))
        layers_a["ln2"] = ("layers", None)
    p["layers"], a["layers"] = layers_p, layers_a
    if cfg.frontend is not None:
        p["frontend_proj"] = jax.random.normal(ks[4], (d, d)) / np.sqrt(d)
        a["frontend_proj"] = ("embed", None)
    return p, a


# ----------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ----------------------------------------------------------------------

def _dense_block(pl, cfg, x, positions, dtype):
    h = rms_norm(x, pl["ln1"], cfg.norm_eps)
    atile, kv = attention_fwd(pl["attn"], cfg, h, positions,
                              window=cfg.swa_window, dtype=dtype)
    x = x + atile
    h = rms_norm(x, pl["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        x = x + moe_lib.moe_fwd(pl["moe"], cfg, h, dtype=dtype)
    else:
        x = x + mlp_fwd(pl["mlp"], h, dtype)
    return x, kv


def _shared_block(sp, cfg, x, x0, positions, dtype, cache=None,
                  decode=False, cache_ctx=None):
    """Zamba2 shared attention+MLP on concat(hidden, initial embedding)."""
    cat = jnp.concatenate([x, x0], axis=-1)
    h = rms_norm(cat, sp["ln1"], cfg.norm_eps)
    if not decode:
        atile, kv = attention_fwd(sp["attn"], cfg, h, positions,
                                  window=cfg.swa_window, dtype=dtype)
    else:
        k_c, v_c, pos_c, q_pos = cache_ctx
        B = x.shape[0]
        H, KV, dh = cfg.n_heads, cfg.n_kv, cfg.d_head
        q = (h @ sp["attn"]["wq"].astype(dtype)).reshape(B, 1, H, dh)
        k = (h @ sp["attn"]["wk"].astype(dtype)).reshape(B, 1, KV, dh)
        v = (h @ sp["attn"]["wv"].astype(dtype)).reshape(B, 1, KV, dh)
        q = rope(q, q_pos[:, None], cfg.rope_theta)
        k = rope(k, q_pos[:, None], cfg.rope_theta)
        kv = (k, v)
        W = k_c.shape[1]
        slot = (q_pos % W).astype(jnp.int32)
        k_c = k_c.at[jnp.arange(B), slot].set(k[:, 0])
        v_c = v_c.at[jnp.arange(B), slot].set(v[:, 0])
        atile = decode_attention(
            q, k_c, v_c, q_position=q_pos,
            kv_positions=pos_c, kv_valid=pos_c >= 0,
            window=cfg.swa_window)
        atile = atile.reshape(B, 1, H * dh) @ sp["attn"]["wo"].astype(dtype)
        kv = (k_c, v_c)
    x = x + atile
    h = rms_norm(x, sp["ln2"], cfg.norm_eps)
    x = x + mlp_fwd(sp["mlp"], h, dtype)
    return x, kv


def backbone(params, cfg: ModelConfig, h, positions, *, dtype=jnp.bfloat16,
             remat: bool = True, collect_cache: bool = False):
    """h: [B, S, d] -> [B, S, d].  collect_cache returns per-layer KV/state."""
    L = cfg.n_layers
    h = constrain(h, "batch", None, None)
    x0 = h
    ckpt = (functools.partial(
        jax.checkpoint,
        policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        if cfg.remat_policy == "dots" else jax.checkpoint)

    if cfg.family in ("ssm", "hybrid"):
        ae = cfg.attn_every
        n_inv = (L + ae - 1) // ae if (cfg.family == "hybrid" and ae) else 0

        def body(carry, inp):
            x, shared_kv = carry
            pl, i = inp
            hh = rms_norm(x, pl["ln"], cfg.norm_eps)
            x = constrain(x, "batch", None, None)
            if collect_cache:
                out, hT, conv = ssm_lib.ssm_block_fwd(
                    pl["ssm"], cfg, hh, dtype=dtype, return_state=True)
            else:
                out = ssm_lib.ssm_block_fwd(pl["ssm"], cfg, hh, dtype=dtype)
                hT = conv = None
            x = x + out
            if n_inv:
                def with_attn(x):
                    return _shared_block(params["shared"], cfg, x, x0,
                                         positions, dtype)
                def no_attn(x):
                    B, S, _ = x.shape
                    z = (jnp.zeros((B, S, cfg.n_kv, cfg.d_head), dtype),) * 2
                    return x, z
                x, kv = jax.lax.cond(i % ae == ae - 1, with_attn, no_attn, x)
                inv = i // ae
                if collect_cache:
                    shared_kv = (
                        jax.lax.dynamic_update_index_in_dim(
                            shared_kv[0], kv[0], inv, 0),
                        jax.lax.dynamic_update_index_in_dim(
                            shared_kv[1], kv[1], inv, 0))
            return (x, shared_kv), (hT, conv)

        if remat:
            body = ckpt(body)
        B, S, _ = h.shape
        skv0 = None
        if n_inv:
            skv0 = (jnp.zeros((n_inv, B, S, cfg.n_kv, cfg.d_head), dtype),
                    jnp.zeros((n_inv, B, S, cfg.n_kv, cfg.d_head), dtype))
        (x, skv), states = jax.lax.scan(
            body, (h, skv0),
            (params["layers"], jnp.arange(L, dtype=jnp.int32)))
        if collect_cache:
            return x, dict(ssm_h=states[0], ssm_conv=states[1],
                           shared_kv=skv)
        return x

    def body(x, inp):
        pl, i = inp
        x, kv = _dense_block(pl, cfg, x, positions, dtype)
        x = constrain(x, "batch", None, None)
        return x, kv if collect_cache else None

    if remat:
        body = ckpt(body)
    x, kvs = jax.lax.scan(body, h,
                          (params["layers"],
                           jnp.arange(L, dtype=jnp.int32)))
    if collect_cache:
        return x, dict(k=kvs[0], v=kvs[1])
    return x


def embed_tokens(params, cfg, tokens, dtype):
    return params["embed"].astype(dtype)[tokens]


def embed_frontend(params, cfg, embeds, dtype):
    return embeds.astype(dtype) @ params["frontend_proj"].astype(dtype)


def lm_head_chunked(params, cfg, x, labels, *, chunk: int = 512,
                    dtype=jnp.bfloat16):
    """Per-token CE without materializing [B, S, V] (scan over seq chunks)."""
    B, S, d = x.shape
    chunk = min(chunk, S)
    nc = S // chunk
    assert S % chunk == 0, "seq must divide the loss chunk"
    emb = params["embed"].astype(dtype)
    norm = params["final_norm"]
    xs = x.reshape(B, nc, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, nc, chunk).transpose(1, 0, 2)

    def step(tot, inp):
        xc, lc = inp
        hc = rms_norm(xc, norm, cfg.norm_eps)
        logits = (hc @ emb.T).astype(jnp.float32)              # [B,c,V]
        logits = constrain(logits, "batch", None, "model")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None],
                                   axis=-1)[..., 0]
        return tot + jnp.sum(lse - gold), None

    # checkpoint: recompute chunk logits in backward instead of stacking
    # [nc, B, chunk, V] residuals (multi-GB at 32k seq)
    tot, _ = jax.lax.scan(jax.checkpoint(step),
                          jnp.zeros((), jnp.float32), (xs, ls))
    return tot / (B * S)


def lm_logits_last(params, cfg, x, dtype=jnp.bfloat16):
    h = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    return (h @ params["embed"].astype(dtype).T).astype(jnp.float32)


def lm_loss(params, cfg: ModelConfig, batch, *, dtype=jnp.bfloat16,
            remat: bool = True):
    """batch: {"tokens": [B,S]} or {"embeds": [B,S,d]} + {"labels": [B,S]}."""
    if cfg.frontend is not None and "embeds" in batch:
        h = embed_frontend(params, cfg, batch["embeds"], dtype)
    else:
        h = embed_tokens(params, cfg, batch["tokens"], dtype)
    B, S = h.shape[:2]
    positions = jnp.arange(S, dtype=jnp.int32)
    x = backbone(params, cfg, h, positions, dtype=dtype, remat=remat)
    return lm_head_chunked(params, cfg, x, batch["labels"], dtype=dtype)
