"""Mixture-of-Experts with PPM-powered dispatch (DESIGN.md §5).

Token -> expert routing *is* partition-centric message passing: tokens are
source vertices, experts are partitions, the router output is the frontier.
Two dispatch modes mirror the paper's dual communication modes:

  * ``dense_dp`` (default): per-batch-row capacity bins + scatter/gather —
    the DC-like dense mode.  Experts are weight-sharded (FSDP over data, TP
    over model); tokens never cross devices, so dispatch costs zero
    collectives and the expert matmuls are plain einsums.
  * ``ppm_ep`` : explicit expert parallelism via the PPM bin exchange: each
    model-axis shard owns ``E/Dm`` experts; per-(device, expert) capacity
    bins are exchanged with one ``all_to_all`` (scatter), expert FFN runs on
    the owning shard (gather), and a second ``all_to_all`` returns outputs.
    This is the paper's 2D bin grid operating as an LM feature; requires
    ``E % model_axis == 0``.

An Eq. 1-style bytes model (`choose_impl`) picks the mode from the routing
statistics, mirroring the paper's per-partition analytical decision.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.sharding import constrain
from .layers import dense_param


def moe_params(key, cfg, n_layers=None):
    d, E, ff = cfg.d_model, cfg.moe_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 7)
    p, a = {}, {}
    p["router"], a["router"] = dense_param(ks[0], d, E, ("embed", None),
                                           n_layers)
    shape = (E, d, ff) if n_layers is None else (n_layers, E, d, ff)
    shape2 = (E, ff, d) if n_layers is None else (n_layers, E, ff, d)
    lx = ("experts", "embed", "ff") if n_layers is None \
        else ("layers", "experts", "embed", "ff")
    lx2 = ("experts", "ff", "embed") if n_layers is None \
        else ("layers", "experts", "ff", "embed")
    sc = 1.0 / np.sqrt(d)
    sc2 = 1.0 / np.sqrt(ff)
    p["w1"] = jax.random.normal(ks[1], shape) * sc
    p["w3"] = jax.random.normal(ks[2], shape) * sc
    p["w2"] = jax.random.normal(ks[3], shape2) * sc2
    a["w1"], a["w3"], a["w2"] = lx, lx, lx2
    if cfg.moe_shared_expert:
        from .layers import mlp_params
        p["shared"], a["shared"] = mlp_params(ks[4], d, ff, n_layers)
    return p, a


def _route(p, cfg, x, dtype):
    """Top-k routing.  Returns (idx [B,S,k], weights [B,S,k])."""
    logits = (x @ p["router"].astype(dtype)).astype(jnp.float32)
    w, idx = jax.lax.top_k(logits, cfg.moe_top_k)
    w = jax.nn.softmax(w, axis=-1)
    return idx, w


def _dispatch_positions(idx, E, capacity):
    """Per-batch-row bin positions (the PPM bin-insertion point walk).

    idx: [B, S, k] expert ids.  Returns pos [B, S, k] position within the
    (row, expert) bin and keep [B, S, k] (capacity drop mask).
    """
    B, S, k = idx.shape

    def per_row(idx_row):                       # [S, k]
        counts = jnp.zeros((E,), jnp.int32)
        poss = []
        for j in range(k):
            oh = jax.nn.one_hot(idx_row[:, j], E, dtype=jnp.int32)  # [S,E]
            ranks = jnp.cumsum(oh, axis=0) - 1                      # [S,E]
            pos_j = jnp.take_along_axis(
                ranks, idx_row[:, j:j + 1], axis=1)[:, 0] \
                + counts[idx_row[:, j]]
            counts = counts + oh.sum(axis=0)
            poss.append(pos_j)
        return jnp.stack(poss, axis=1)          # [S, k]

    pos = jax.vmap(per_row)(idx)
    keep = pos < capacity
    return pos, keep


def moe_fwd_dense(p, cfg, x, *, dtype=jnp.bfloat16):
    """DC-like dense capacity dispatch, data-parallel experts."""
    B, S, d = x.shape
    E, k = cfg.moe_experts, cfg.moe_top_k
    cap = int(np.ceil(S * k / E * cfg.moe_capacity))
    idx, wts = _route(p, cfg, x, dtype)
    pos, keep = _dispatch_positions(idx, E, cap)

    # scatter tokens into bins [B, E*cap, d]
    flat_slot = jnp.where(keep, idx * cap + pos, E * cap)       # [B,S,k]
    xe = jnp.zeros((B, E * cap + 1, d), dtype)
    for j in range(k):
        xe = xe.at[jnp.arange(B)[:, None], flat_slot[:, :, j]].add(x)
    xe = xe[:, :-1].reshape(B, E, cap, d)

    # expert FFN (einsum over experts).  Default: experts replicated,
    # ff TP-sharded.  moe_ep: expert-parallel — bins constrained onto the
    # expert shards, which turns the dispatch into the PPM all_to_all
    # (XLA inserts it from the batch->expert sharding transition).
    w1 = p["w1"].astype(dtype)
    w3 = p["w3"].astype(dtype)
    w2 = p["w2"].astype(dtype)
    if cfg.moe_ep:
        xe = constrain(xe, "batch", "model", None, None)
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, w1)) \
            * jnp.einsum("becd,edf->becf", xe, w3)
        h = constrain(h, "batch", "model", None, None)
        ye = jnp.einsum("becf,efd->becd", h, w2).reshape(B, E * cap, d)
        ye = constrain(ye, "batch", None, None)
    else:
        xe = constrain(xe, "batch", None, None, None)
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, w1)) \
            * jnp.einsum("becd,edf->becf", xe, w3)
        h = constrain(h, "batch", None, None, "model")
        ye = jnp.einsum("becf,efd->becd", h, w2).reshape(B, E * cap, d)
        ye = constrain(ye, "batch", None, None)
    ye = jnp.concatenate([ye, jnp.zeros((B, 1, d), dtype)], axis=1)

    # combine (gather back with router weights)
    out = jnp.zeros((B, S, d), dtype)
    for j in range(k):
        yj = jnp.take_along_axis(
            ye, flat_slot[:, :, j:j + 1].reshape(B, S, 1), axis=1)
        out = out + yj * (wts[:, :, j] * keep[:, :, j])[..., None].astype(dtype)

    if cfg.moe_shared_expert:
        from .layers import mlp_fwd
        out = out + mlp_fwd(p["shared"], x, dtype)
    return out


def moe_fwd_ppm_ep(p, x=None, mesh_axis="model", *, cfg=None,
                   dtype=jnp.bfloat16):
    """PPM expert-parallel dispatch (inside shard_map over the model axis).

    Must be called under shard_map with ``mesh_axis`` unsplit in x.
    Each shard owns E_loc experts; bins bin[shard][expert] are exchanged
    with one all_to_all per direction — the paper's Scatter/Gather phases.
    """
    Dm = jax.lax.axis_size(mesh_axis)
    B, S, d = x.shape
    E, k = cfg.moe_experts, cfg.moe_top_k
    assert E % Dm == 0, "ppm_ep needs experts % model-axis == 0"
    E_loc = E // Dm
    cap = int(np.ceil(S * k / E * cfg.moe_capacity))
    idx, wts = _route(p, cfg, x, dtype)
    pos, keep = _dispatch_positions(idx, E, cap)

    flat_slot = jnp.where(keep, idx * cap + pos, E * cap)
    xe = jnp.zeros((B, E * cap + 1, d), dtype)
    for j in range(k):
        xe = xe.at[jnp.arange(B)[:, None], flat_slot[:, :, j]].add(x)
    xe = xe[:, :-1].reshape(B, E, cap, d)

    # ---- PPM scatter: bins -> owning expert shard ----
    # [B, Dm, E_loc, cap, d] -> all_to_all over Dm
    xe = xe.reshape(B, Dm, E_loc, cap, d).transpose(1, 0, 2, 3, 4)
    xe = jax.lax.all_to_all(xe, mesh_axis, 0, 0)   # rows now = source shards
    # gather phase: this shard's experts process all sources' bins
    xe = xe.transpose(1, 2, 0, 3, 4).reshape(B, E_loc, Dm * cap, d)

    w1 = p["w1"].astype(dtype)    # local slice [E_loc, d, ff] under shard_map
    w3 = p["w3"].astype(dtype)
    w2 = p["w2"].astype(dtype)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, w1)) \
        * jnp.einsum("becd,edf->becf", xe, w3)
    ye = jnp.einsum("becf,efd->becd", h, w2)

    # ---- PPM return scatter ----
    ye = ye.reshape(B, E_loc, Dm, cap, d).transpose(2, 0, 1, 3, 4)
    ye = jax.lax.all_to_all(ye, mesh_axis, 0, 0)
    ye = ye.transpose(1, 0, 2, 3, 4).reshape(B, E * cap, d)
    ye = jnp.concatenate([ye, jnp.zeros((B, 1, d), dtype)], axis=1)

    out = jnp.zeros((B, S, d), dtype)
    for j in range(k):
        yj = jnp.take_along_axis(
            ye, flat_slot[:, :, j:j + 1].reshape(B, S, 1), axis=1)
        out = out + yj * (wts[:, :, j] * keep[:, :, j])[..., None].astype(dtype)
    if cfg.moe_shared_expert:
        from .layers import mlp_fwd
        out = out + mlp_fwd(p["shared"], x, dtype, constrained=False)
    return out


def moe_fwd_ppm_ep_sharded(p, cfg, x, *, dtype=jnp.bfloat16):
    """shard_map wrapper for the explicit PPM dispatch: called from inside
    the (auto-sharded) model; drops into manual collectives over the model
    axis.  Falls back to dense_dp when no mesh is active (tests) or the
    expert count does not divide the model axis (mixtral on 16-way TP)."""
    from ..dist.sharding import _ACT_MESH, _collapse, _data_axes
    mesh = _ACT_MESH[0]
    if mesh is None or "model" not in mesh.axis_names \
            or cfg.moe_experts % mesh.shape["model"] != 0:
        return moe_fwd_dense(p, cfg, x, dtype=dtype)
    from ..dist.compat import PartitionSpec as P, shard_map
    db = _collapse(_data_axes(mesh))

    def spec_of(path_leaf):
        name = path_leaf[0].key if hasattr(path_leaf[0], "key") else ""
        return name

    # per-leaf specs: expert tensors sharded on E over model; rest replicated
    def leaf_spec(path, leaf):
        keys = [getattr(k, "key", "") for k in path]
        if keys[0] in ("w1", "w3", "w2"):
            return P("model", *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))

    if x.shape[1] % mesh.shape["model"] != 0:
        return moe_fwd_dense(p, cfg, x, dtype=dtype)
    p_specs = jax.tree_util.tree_map_with_path(leaf_spec, p)
    fn = functools.partial(moe_fwd_ppm_ep, cfg=cfg, dtype=dtype)
    # tokens are sequence-split over the model axis: each shard dispatches
    # ONLY its S/Dm token slice (x replicated over model would make every
    # shard bin the same tokens - a 16x compute redundancy, observed)
    return shard_map(
        lambda pp, xx: fn(pp, x=xx),
        mesh=mesh,
        in_specs=(p_specs, P(db, "model", None)),
        out_specs=P(db, "model", None),
        check_vma=False,
    )(p, x)


def moe_fwd(p, cfg, x, *, impl=None, dtype=jnp.bfloat16, **kw):
    impl = impl or cfg.moe_impl
    if impl == "ppm_ep":
        return moe_fwd_ppm_ep_sharded(p, cfg, x, dtype=dtype)
    return moe_fwd_dense(p, cfg, x, dtype=dtype)


def moe_ref(p, cfg, x):
    """Oracle: loop over tokens/experts in fp32, no capacity drops."""
    B, S, d = x.shape
    idx, wts = _route(p, cfg, x, jnp.float32)
    out = np.zeros((B, S, d), np.float32)
    xn = np.asarray(x, np.float32)
    w1 = np.asarray(p["w1"], np.float32)
    w3 = np.asarray(p["w3"], np.float32)
    w2 = np.asarray(p["w2"], np.float32)
    idx = np.asarray(idx)
    wts = np.asarray(wts)

    def silu(v):
        return v / (1.0 + np.exp(-v))

    for b in range(B):
        for s in range(S):
            for j in range(cfg.moe_top_k):
                e = idx[b, s, j]
                h = silu(xn[b, s] @ w1[e]) * (xn[b, s] @ w3[e])
                out[b, s] += wts[b, s, j] * (h @ w2[e])
    if cfg.moe_shared_expert:
        for b in range(B):
            for s in range(S):
                sh = p["shared"]
                h = silu(xn[b, s] @ np.asarray(sh["w1"], np.float32)) \
                    * (xn[b, s] @ np.asarray(sh["w3"], np.float32))
                out[b, s] += h @ np.asarray(sh["w2"], np.float32)
    return out
