"""Model configuration covering all assigned architecture families.

One dataclass describes dense/GQA, MoE, SSM (Mamba2/SSD), hybrid (Zamba2),
and stub-frontend (VLM/audio) transformers.  Exact per-arch values live in
``repro.configs.<id>``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                  # query heads (0 for attn-free)
    n_kv: int                     # kv heads (GQA)
    d_head: int
    d_ff: int
    vocab: int
    # attention options
    qkv_bias: bool = False
    swa_window: Optional[int] = None     # sliding-window attention
    rope_theta: float = 10_000.0
    causal: bool = True                  # False for encoder-only (hubert)
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_shared_expert: bool = False      # llama4-style shared expert
    moe_capacity: float = 1.25
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 128
    ssm_intra_bf16: bool = False   # bf16 intra-chunk SSD matmuls (hillclimb)
    # hybrid (Zamba2): a shared attention block every `attn_every` layers
    attn_every: int = 0
    # frontend stub: None | "patch" (vlm) | "frame" (audio)
    frontend: Optional[str] = None
    # numerics
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    # sharding variants (hillclimb levers; see EXPERIMENTS.md section Perf)
    sharding_overrides: tuple = ()    # ((logical_axis, mesh_axis|None), ...)
    moe_ep: bool = False              # expert-parallel MoE (experts->model)
    moe_impl: str = "dense_dp"        # dense_dp | ppm_ep (shard_map bins)
    remat_policy: str = "full"        # full | dots (save matmul outputs)

    def act_axis(self, logical: str):
        """Mesh axis for activation constraints of a logical dim, honoring
        sharding_overrides (None = replicate)."""
        for k, v in self.sharding_overrides:
            if k == logical:
                return v
        return "model"

    @property
    def is_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def is_moe(self) -> bool:
        return self.moe_experts > 0

    @property
    def decoder(self) -> bool:
        return self.causal

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context? (SSM state, or SWA window)"""
        if self.family in ("ssm",):
            return True
        if self.family == "hybrid":
            return True     # SSM backbone + a few shared-attn KV reads
        return self.swa_window is not None

    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6*N*D)."""
        d, L = self.d_model, self.n_layers
        n = self.vocab * d                           # embedding (tied head)
        per_layer = 0
        if self.family in ("ssm", "hybrid"):
            di, ns, hs = self.d_inner, self.ssm_state, self.ssm_heads
            # in_proj (z,x,B,C,dt) + out_proj + conv + D + A + norm
            per_layer = d * (2 * di + 2 * ns + hs) + di * d \
                + self.ssm_conv * (di + 2 * ns) + 2 * hs + di
            n += per_layer * L
            if self.family == "hybrid" and self.attn_every:
                # one shared attention+MLP block (counted once - weights shared)
                hd = self.n_heads * self.d_head
                kvd = self.n_kv * self.d_head
                # zamba2 concatenates (hidden, residual) into the shared block
                n += 2 * d * hd + 2 * d * kvd + hd * d + 3 * d * self.d_ff
            return n
        hd = self.n_heads * self.d_head
        kvd = self.n_kv * self.d_head
        attn = d * hd + 2 * d * kvd + hd * d
        if self.is_moe:
            mlp = 3 * d * self.moe_d_ff * self.moe_experts
            if self.moe_shared_expert:
                mlp += 3 * d * self.moe_d_ff
            # router
            mlp += d * self.moe_experts
        else:
            mlp = 3 * d * self.d_ff
        n += (attn + mlp) * L
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if not self.is_moe:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        hd = self.n_heads * self.d_head
        kvd = self.n_kv * self.d_head
        attn = d * hd + 2 * d * kvd + hd * d
        mlp = 3 * d * self.moe_d_ff * self.moe_top_k + d * self.moe_experts
        if self.moe_shared_expert:
            mlp += 3 * d * self.moe_d_ff
        return self.vocab * d + (attn + mlp) * L
