from .config import ModelConfig
from .transformer import init_lm, lm_loss, backbone

__all__ = ["ModelConfig", "init_lm", "lm_loss", "backbone"]
