from .optimizer import OptConfig, adamw_update, init_opt_state, lr_at
from .train_step import make_train_step, jit_train_step
from .data import DataConfig, TokenPipeline
from . import checkpoint
