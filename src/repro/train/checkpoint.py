"""Sharded checkpointing with atomic commit, elastic re-sharding, and an
async mode.

Format: one ``.npz`` of flattened leaves (host-gathered) + a JSON manifest
(step, leaf paths).  Save is write-to-temp + atomic rename, so a preemption
mid-save never corrupts the latest checkpoint.  Load is mesh-agnostic: leaves
are re-``device_put`` under whatever shardings the *current* mesh dictates —
restart on 8 devices, resume on 512 (elastic scaling).

``AsyncCheckpointer`` overlaps the host-side serialization with training:
device buffers are fetched synchronously (cheap, device->host DMA), the
npz write happens on a worker thread, and ``wait()`` joins at the next save
or at exit — the standard production pattern for large-state jobs.
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
from typing import Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(path: str, step: int, params, opt_state, extra: Optional[dict] = None):
    os.makedirs(path, exist_ok=True)
    leaves_p, _ = _flatten(params)
    leaves_o, _ = _flatten(opt_state)
    arrays = {f"p_{i}": np.asarray(jax.device_get(x))
              for i, x in enumerate(leaves_p)}
    arrays.update({f"o_{i}": np.asarray(jax.device_get(x))
                   for i, x in enumerate(leaves_o)})
    manifest = {"step": int(step), "n_params": len(leaves_p),
                "n_opt": len(leaves_o), "extra": extra or {}}
    fd, tmp = tempfile.mkstemp(dir=path, suffix=".tmp")
    os.close(fd)
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    final = os.path.join(path, f"ckpt_{step:08d}.npz")
    os.replace(tmp, final)
    mtmp = tmp + ".json"
    with open(mtmp, "w") as f:
        json.dump(manifest, f)
    os.replace(mtmp, os.path.join(path, f"ckpt_{step:08d}.json"))
    _update_latest(path, step)
    return final


def _update_latest(path: str, step: int):
    tmp = os.path.join(path, "LATEST.tmp")
    with open(tmp, "w") as f:
        f.write(str(step))
    os.replace(tmp, os.path.join(path, "LATEST"))


def latest_step(path: str) -> Optional[int]:
    f = os.path.join(path, "LATEST")
    if not os.path.exists(f):
        return None
    return int(open(f).read().strip())


def restore(path: str, params_like, opt_like, step: Optional[int] = None,
            shardings=None, opt_shardings=None):
    """Restore onto the current mesh (elastic re-shard via device_put)."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {path}")
    data = np.load(os.path.join(path, f"ckpt_{step:08d}.npz"))
    leaves_p, treedef_p = _flatten(params_like)
    leaves_o, treedef_o = _flatten(opt_like)
    new_p = [data[f"p_{i}"] for i in range(len(leaves_p))]
    new_o = [data[f"o_{i}"] for i in range(len(leaves_o))]
    params = jax.tree_util.tree_unflatten(treedef_p, new_p)
    opt = jax.tree_util.tree_unflatten(treedef_o, new_o)
    if shardings is not None:
        params = jax.tree_util.tree_map(jax.device_put, params, shardings)
    if opt_shardings is not None:
        opt = jax.tree_util.tree_map(jax.device_put, opt, opt_shardings)
    return params, opt, step


class AsyncCheckpointer:
    """Overlap checkpoint serialization with training."""

    def __init__(self, path: str):
        self.path = path
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, params, opt_state, extra=None):
        self.wait()                           # one in-flight save at a time
        # fetch to host NOW (so training may donate/overwrite device buffers)
        host_p = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), params)
        host_o = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), opt_state)

        def work():
            try:
                save(self.path, step, host_p, host_o, extra)
            except BaseException as e:        # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
