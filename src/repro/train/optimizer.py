"""AdamW from scratch (+ LR schedules, grad clip, int8 error-feedback
compression, bf16 low-precision-gradients support).

Mixed precision contract: the *compute* params handed to the forward pass may
be bf16 (halving FSDP all-gather and grad reduce-scatter bytes — the
"gradient compression" lever that actually shows up in the HLO collectives);
the optimizer keeps an f32 master copy plus f32 (m, v).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    int8_compress: bool = False          # int8 grads + error feedback
    master_dtype: str = "float32"
    compute_dtype: str = "bfloat16"


def lr_at(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.lr * (step + 1) / max(cfg.warmup, 1)
    prog = jnp.clip((step - cfg.warmup)
                    / max(cfg.total_steps - cfg.warmup, 1), 0.0, 1.0)
    cos = 0.5 * cfg.lr * (1 + jnp.cos(np.pi * prog))
    return jnp.where(step < cfg.warmup, warm, cos)


def init_opt_state(params, cfg: OptConfig):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    st = {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    # keep an f32 master copy only when the compute params are low precision
    if cfg.compute_dtype != "float32":
        st["master"] = jax.tree_util.tree_map(
            lambda p: p.astype(jnp.float32), params)
    if cfg.int8_compress:
        st["ef"] = jax.tree_util.tree_map(zeros, params)
    return st


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree_util.tree_leaves(tree)))


def _quantize_int8(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def adamw_update(params, grads, state, cfg: OptConfig):
    """Returns (new compute params, new state, metrics)."""
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree_util.tree_map(
        lambda g: g.astype(jnp.float32) * scale, grads)

    if cfg.int8_compress:
        def comp(g, ef):
            q, s = _quantize_int8(g + ef)
            deq = q.astype(jnp.float32) * s
            return deq, (g + ef) - deq
        pairs = jax.tree_util.tree_map(comp, grads, state["ef"])
        grads = jax.tree_util.tree_map(lambda x: x[0], pairs,
                                       is_leaf=lambda x: isinstance(x, tuple))
        new_ef = jax.tree_util.tree_map(lambda x: x[1], pairs,
                                        is_leaf=lambda x: isinstance(x, tuple))
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(master, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        new = master - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                             + cfg.weight_decay * master)
        return new, m, v

    master = state.get(
        "master",
        jax.tree_util.tree_map(lambda p: p.astype(jnp.float32), params))
    out = jax.tree_util.tree_map(upd, master, grads,
                                 state["m"], state["v"])
    new_master = jax.tree_util.tree_map(
        lambda x: x[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(
        lambda x: x[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(
        lambda x: x[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_state = dict(state, m=new_m, v=new_v, step=step)
    if "master" in state:
        new_state["master"] = new_master
        cdt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" \
            else jnp.float32
        new_params = jax.tree_util.tree_map(
            lambda p: p.astype(cdt), new_master)
    else:
        new_params = new_master
    if cfg.int8_compress:
        new_state["ef"] = new_ef
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
