"""Deterministic synthetic data pipeline (+ memmap file mode).

Step-addressable: ``batch_at(step)`` is a pure function of (seed, step), so a
restarted/elastically-rescaled job resumes mid-stream with no state to
recover — the data side of fault tolerance.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    path: Optional[str] = None     # binary int32 token file (memmap mode)
    embed_dim: Optional[int] = None  # for frontend-stub archs


class TokenPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._mm = None
        if cfg.path is not None:
            self._mm = np.memmap(cfg.path, dtype=np.int32, mode="r")

    def batch_at(self, step: int) -> dict:
        c = self.cfg
        if self._mm is not None:
            need = c.global_batch * (c.seq_len + 1)
            start = (step * need) % max(len(self._mm) - need, 1)
            flat = np.asarray(self._mm[start:start + need])
            toks = flat.reshape(c.global_batch, c.seq_len + 1) % c.vocab
        else:
            rng = np.random.default_rng((c.seed << 32) ^ step)
            toks = rng.integers(0, c.vocab,
                                (c.global_batch, c.seq_len + 1),
                                dtype=np.int32)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if c.embed_dim is not None:
            rng = np.random.default_rng((c.seed << 32) ^ (step + 1 << 20))
            batch["embeds"] = rng.normal(
                size=(c.global_batch, c.seq_len, c.embed_dim)
            ).astype(np.float32)
            del batch["tokens"]
        return batch
