"""Sharded train step builder: value_and_grad + microbatch accumulation +
AdamW, jitted with explicit in/out shardings over the production mesh.

Compute/communication overlap comes from two structural choices:
  * FSDP all-gathers are per-layer inside the scanned block, so XLA overlaps
    the gather of layer i+1 with compute of layer i (latency hiding);
  * with ``microbatches > 1`` the gradient accumulation scan keeps the
    backward collectives of microbatch j overlapping the forward of j+1.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..dist.sharding import (batch_spec, default_rules, param_shardings,
                             set_activation_mesh)
from ..models.config import ModelConfig
from ..models.transformer import lm_loss
from .optimizer import OptConfig, adamw_update, init_opt_state


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig, mesh,
                    axes_tree=None, params=None, *, microbatches: int = 1,
                    remat: bool = True, rules=None, moe_impl: str = "dense_dp"):
    """Returns (jitted step fn, shardings dict).

    step(params, opt_state, batch) -> (params, opt_state, metrics)
    """
    rules = rules or default_rules(mesh, cfg)
    set_activation_mesh(mesh)
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    def loss_fn(p, batch):
        return lm_loss(p, cfg, batch, dtype=dtype, remat=remat)

    def step(params, opt_state, batch):
        if microbatches > 1:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            mb = jax.tree_util.tree_map(split, batch)

            def acc_step(carry, mbatch):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_fn)(params, mbatch)
                gsum = jax.tree_util.tree_map(jnp.add, gsum, g)
                return (gsum, lsum + l), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(acc_step, (g0, 0.0), mb)
            grads = jax.tree_util.tree_map(
                lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_params, new_opt, metrics = adamw_update(
            params, grads, opt_state, opt_cfg)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    shardings = None
    if axes_tree is not None and params is not None:
        p_sh = param_shardings(axes_tree, params, mesh, rules)
        rep = NamedSharding(mesh, P())
        opt_sh = {"m": p_sh, "v": p_sh, "step": rep}
        if opt_cfg.compute_dtype != "float32":
            opt_sh["master"] = p_sh
        if opt_cfg.int8_compress:
            opt_sh["ef"] = p_sh
        b_sh = NamedSharding(mesh, batch_spec(mesh))
        b3_sh = NamedSharding(
            mesh, P(*(tuple(batch_spec(mesh)) + (None,))))
        shardings = dict(params=p_sh, opt=opt_sh, batch2d=b_sh,
                         batch3d=b3_sh, metrics=rep)
    return step, shardings


def jit_train_step(step, shardings, batch_keys=("tokens", "labels")):
    batch_sh = {k: (shardings["batch3d"] if k == "embeds"
                    else shardings["batch2d"]) for k in batch_keys}
    return jax.jit(
        step,
        in_shardings=(shardings["params"], shardings["opt"], batch_sh),
        out_shardings=(shardings["params"], shardings["opt"],
                       shardings["metrics"]),
        donate_argnums=(0, 1))
