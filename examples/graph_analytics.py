"""All five paper applications through the GPOP 4-function API, plus the
dual-mode comparison (paper Fig. 9 in miniature).

  PYTHONPATH=src python examples/graph_analytics.py [scale]
"""
import sys

import numpy as np

from repro.apps import bfs, connected_components, nibble, pagerank, sssp
from repro.graph import build_layout, from_edges, rmat

scale = int(sys.argv[1]) if len(sys.argv) > 1 else 11
g = rmat(scale, 16, seed=1)
gw = rmat(scale, 16, seed=1, weighted=True)
L = build_layout(g, k=32)
Lw = build_layout(gw, k=32)
src = int(np.argmax(g.out_degrees()))

print("== BFS ==")
r = bfs(L, src)
print(f"levels: max={r['level'].max()} reached={(r['level'] >= 0).sum()}")

print("== SSSP (Bellman-Ford) ==")
r = sssp(Lw, src)
fin = np.isfinite(r["dist"])
print(f"reachable={fin.sum()} mean_dist={r['dist'][fin].mean():.3f}")

print("== PageRank ==")
pr = pagerank(L, iters=10)["pr"]
print(f"mass={pr.sum():.4f} max={pr.max():.5f}")

print("== Connected components (label propagation) ==")
srcs = np.repeat(np.arange(g.n), g.out_degrees())
gs = from_edges(np.concatenate([srcs, g.indices]),
                np.concatenate([g.indices, srcs]), n=g.n, dedup=True)
Ls = build_layout(gs, k=32)
cc = connected_components(Ls)["label"]
print(f"components={len(np.unique(cc))}")

print("== Nibble (seeded random walk, selective frontier continuity) ==")
r = nibble(L, seeds=[src], eps=1e-4, max_iters=50)
print(f"mass={r['pr'].sum():.4f} support={(r['pr'] > 0).sum()} "
      f"iters={len(r['stats'])}")

print("== dual-mode engine comparison (BFS) ==")
for mode in ("hybrid", "sc", "dc"):
    st = bfs(L, src, mode=mode)["stats"]
    mb = sum(s.dc_bytes + s.sc_bytes for s in st) / 1e6
    print(f"  {mode:7s}: iters={len(st):3d} modeled_traffic={mb:8.2f} MB")
