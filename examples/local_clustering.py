"""Strongly-local clustering with Nibble (paper §5): the showcase for
selective frontier continuity + amortized work-efficiency.

Many Nibble runs reuse ONE graph layout; each run only touches the seed's
neighborhood (theoretical efficiency), so the O(E) preprocessing amortizes —
the paper's argument for why PPM suits local clustering while O(E)/iteration
frameworks do not.

  PYTHONPATH=src python examples/local_clustering.py
"""
import numpy as np

from repro.apps import nibble
from repro.graph import build_layout, rmat

g = rmat(12, 16, seed=3)
L = build_layout(g, k=32)
full_sweep_bytes = float(L.dc_cost_bytes().sum())
deg = g.out_degrees()
seeds = np.argsort(deg)[-5:]

print(f"graph n={g.n} m={g.m}; one full DC sweep = "
      f"{full_sweep_bytes/1e6:.1f} MB modeled traffic\n")
for s in seeds:
    r = nibble(L, seeds=[int(s)], eps=5e-4, max_iters=40)
    pr = r["pr"]
    touched = sum(st.dc_bytes + st.sc_bytes for st in r["stats"])
    cluster = np.argsort(pr)[::-1][:20]
    cluster = cluster[pr[cluster] > 0]
    print(f"seed {int(s):6d} (deg {int(deg[s]):4d}): "
          f"support={(pr > 0).sum():5d} mass={pr.sum():.3f} "
          f"traffic={touched/1e6:7.2f} MB "
          f"({100*touched/full_sweep_bytes:5.1f}% of a full sweep) "
          f"cluster head={list(map(int, cluster[:5]))}")
