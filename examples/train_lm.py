"""End-to-end LM training driver: data pipeline -> sharded train step ->
checkpoint/restart, on a reduced config of an assigned architecture.

  PYTHONPATH=src python examples/train_lm.py --arch qwen2-0.5b --steps 200
  PYTHONPATH=src python examples/train_lm.py --resume ...      # restart

Defaults are laptop-sized (reduced config, ~200 steps); pass --full to train
the real config (needs real hardware).
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.launch.mesh import make_local_mesh
from repro.models.transformer import init_lm
from repro.train import (DataConfig, OptConfig, TokenPipeline, checkpoint,
                         init_opt_state, jit_train_step, make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    print(f"training {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"~{cfg.param_count()/1e6:.1f}M params")
    mesh = make_local_mesh()
    params, axes = init_lm(cfg, jax.random.PRNGKey(0))
    ocfg = OptConfig(lr=3e-4, warmup=20, total_steps=args.steps,
                     compute_dtype=cfg.dtype)
    opt = init_opt_state(params, ocfg)
    if ocfg.compute_dtype == "bfloat16":
        params = jax.tree_util.tree_map(
            lambda p: p.astype(jnp.bfloat16), params)
    step_fn, sh = make_train_step(cfg, ocfg, mesh, axes, params)
    jstep = jit_train_step(
        step_fn, sh,
        batch_keys=("embeds", "labels") if cfg.frontend else
        ("tokens", "labels"))
    pipe = TokenPipeline(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch, seed=0,
        embed_dim=cfg.d_model if cfg.frontend else None))

    start = 0
    if args.resume and checkpoint.latest_step(args.ckpt) is not None:
        params, opt, start = checkpoint.restore(args.ckpt, params, opt)
        print(f"resumed from step {start}")

    t0 = time.time()
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        params, opt, m = jstep(params, opt, batch)
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss {float(m['loss']):.4f} "
                  f"lr {float(m['lr']):.2e} gnorm {float(m['grad_norm']):.3f} "
                  f"({(time.time()-t0):.1f}s)")
        if (i + 1) % args.ckpt_every == 0:
            checkpoint.save(args.ckpt, i + 1, params, opt)
    checkpoint.save(args.ckpt, args.steps, params, opt)
    print("done; checkpoint at", args.ckpt)


if __name__ == "__main__":
    main()
