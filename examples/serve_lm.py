"""Batched serving driver: slot-based continuous batching over a reduced
assigned-architecture config.

  PYTHONPATH=src python examples/serve_lm.py --arch mixtral-8x7b --requests 6
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models.transformer import init_lm
from repro.serve import Request, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if not cfg.decoder:
        raise SystemExit(f"{args.arch} is encoder-only - no decode serving")
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    import jax.numpy as jnp
    srv = Server(params, cfg, n_slots=args.slots, max_len=128,
                 dtype=jnp.float32)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for r in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, rng.integers(4, 12),
                              dtype=np.int32)
        srv.submit(Request(rid=r, prompt=prompt, max_new=args.max_new))
    done = srv.run()
    dt = time.time() - t0
    tok = sum(len(d.out) for d in done)
    print(f"served {len(done)} requests / {tok} tokens in {dt:.1f}s "
          f"({tok/dt:.1f} tok/s on CPU) with {args.slots} slots")
    for d in sorted(done, key=lambda d: d.rid)[:3]:
        print(f"  req {d.rid}: {d.out[:8]}...")


if __name__ == "__main__":
    main()
