"""Quickstart: partition-centric PageRank + BFS in ~20 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.apps import bfs, pagerank
from repro.graph import build_layout, rmat

# 1. a scale-free graph (paper's RMAT family) and its partition-centric
#    layout: k cache/VMEM-sized partitions + the 2D bin grid / PNG structure
g = rmat(12, 16, seed=1)
layout = build_layout(g, k=32)
print(f"graph: n={g.n} m={g.m}; layout: k={layout.k} partitions of "
      f"q={layout.q} vertices, r={layout.num_msgs/g.m:.2f} msgs/edge")

# 2. PageRank: all vertices active -> pure destination-centric mode,
#    values-only messages over the pre-written dc_bin adjacency
pr = pagerank(layout, iters=10)["pr"]
top = np.argsort(pr)[-3:][::-1]
print("top-3 PageRank:", [(int(v), float(pr[v])) for v in top])

# 3. BFS: the frontier sweeps sparse->dense->sparse; each partition picks
#    SC or DC per iteration from the Eq. 1 cost model
res = bfs(layout, source=int(top[0]), mode="hybrid")
for s in res["stats"]:
    print(f"  iter {s.it}: frontier={s.n_active:6d} active_edges="
          f"{s.e_active:7d} dc_parts={s.dc_parts:3d} sc_parts={s.sc_parts:3d}")
print("reached:", int((res['level'] >= 0).sum()), "/", g.n)
